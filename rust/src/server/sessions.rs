//! Continuous multi-session serving: the scheduler that replaced the
//! single-tenant FCFS worker.
//!
//! One worker thread still owns the engine (the device is single-tenant —
//! submission order is execution order), but instead of running each
//! request to completion it keeps up to `max_sessions` resumable
//! [`DecodeTask`]s live and round-robins **one `step()` per session per
//! scheduling round**. Every live client therefore streams tokens every
//! round — a long generation can no longer block every client behind it —
//! and the serving regime becomes iteration-level interleaving (the
//! SpecInfer/vLLM-style continuous batching discipline, at step rather
//! than batch granularity).
//!
//! * **Admission control** — a job leaves the queue only when a session
//!   slot is free, and its freshly opened task must report enough
//!   [`DecodeTask::headroom`] (KV-slot budget, via
//!   `engine::Session::headroom`) to cover the prompt; otherwise the
//!   request is rejected with a typed error before any device work.
//! * **Cancellation** — each connection owns a cancel flag, raised when
//!   the client disconnects (reader EOF or a failed write). The scheduler
//!   checks it before every step and simply drops the session: the task
//!   owns its KV caches, so the drop frees them immediately and the slot
//!   admits the next queued request in the same round.
//! * **Metrics** — per-request queueing delay, time-to-first-token and
//!   decode throughput are recorded into the shared
//!   [`ServerStats`](super::ServerStats) recorder and echoed on each
//!   `done` event.
//!
//! Worker→connection traffic is the typed [`ServerEvent`] enum; JSON only
//! exists at the connection boundary (`ServerEvent::to_json`). The old
//! per-request pump that sniffed `"event":"done"` substrings is gone
//! entirely: one writer pump per connection forwards every event and
//! request lifetimes are tracked by the scheduler, not the wire format.

use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use crate::engine::{DecodeTask, StepEngine, StepOutcome};
use crate::util::json::Json;

use super::{CancelFlag, ServerStats, StatsSnapshot};

/// Sliding window for the per-request serving series: bounds the stats
/// recorder's memory (and each snapshot's percentile scan) on servers
/// that run indefinitely.
const STATS_WINDOW: usize = 4096;

/// Final per-request summary carried by [`ServerEvent::Done`].
#[derive(Debug, Clone)]
pub struct DoneSummary {
    /// Generated tokens (complete sequence).
    pub tokens: Vec<u32>,
    /// Average accepted length.
    pub aal: f64,
    /// Per-token latency (ms).
    pub tpot_ms: f64,
    /// Verification iterations used.
    pub iterations: usize,
    /// Prompt prefill time (ms).
    pub prefill_ms: f64,
    /// Time the request waited in the queue before admission.
    pub queue_ms: f64,
    /// Enqueue → first committed token (NaN when nothing was generated).
    pub ttft_ms: f64,
    /// Decode throughput over the session's admitted lifetime.
    pub tok_per_s: f64,
}

/// Typed worker→connection event stream. One connection multiplexes many
/// requests; `id` keys the demux client-side.
#[derive(Debug, Clone)]
pub enum ServerEvent {
    /// Tokens committed by one scheduling step (stream mode only).
    Tokens { id: u64, tokens: Vec<u32> },
    /// Generation finished.
    Done { id: u64, summary: DoneSummary },
    /// Request-level failure. `id` is `None` for lines that never parsed
    /// far enough to have one.
    Error { id: Option<u64>, message: String },
    /// Reply to a `{"stats": true}` request (produced connection-side).
    Stats(StatsSnapshot),
}

impl ServerEvent {
    /// Wire form (one JSON object per line). Ids serialize via
    /// [`Json::from_u64`], so they survive the full u64 range.
    pub fn to_json(&self) -> Json {
        match self {
            ServerEvent::Tokens { id, tokens } => Json::obj(vec![
                ("id", Json::from_u64(*id)),
                ("event", Json::Str("tokens".into())),
                ("tokens", Json::Arr(tokens.iter().map(|&t| Json::Num(t as f64)).collect())),
            ]),
            ServerEvent::Done { id, summary } => Json::obj(vec![
                ("id", Json::from_u64(*id)),
                ("event", Json::Str("done".into())),
                (
                    "tokens",
                    Json::Arr(summary.tokens.iter().map(|&t| Json::Num(t as f64)).collect()),
                ),
                ("aal", Json::Num(summary.aal)),
                ("tpot_ms", Json::Num(summary.tpot_ms)),
                ("iterations", Json::Num(summary.iterations as f64)),
                ("prefill_ms", Json::Num(summary.prefill_ms)),
                ("queue_ms", Json::Num(summary.queue_ms)),
                ("ttft_ms", Json::Num(summary.ttft_ms)),
                ("tok_per_s", Json::Num(summary.tok_per_s)),
            ]),
            ServerEvent::Error { id, message } => {
                let mut fields = Vec::new();
                if let Some(id) = id {
                    fields.push(("id", Json::from_u64(*id)));
                }
                fields.push(("event", Json::Str("error".into())));
                fields.push(("message", Json::Str(message.clone())));
                Json::obj(fields)
            }
            ServerEvent::Stats(s) => s.to_json(),
        }
    }
}

/// One queued generation request.
pub struct Job {
    /// Client-chosen request id (demux key).
    pub id: u64,
    /// Tokenized prompt.
    pub prompt: Vec<u32>,
    /// Generation budget.
    pub max_new: usize,
    /// Event channel back to the owning connection's writer pump.
    pub reply: mpsc::Sender<ServerEvent>,
    /// Emit per-step `tokens` events.
    pub stream: bool,
    /// Connection-level cancel flag (client disconnected).
    pub cancelled: CancelFlag,
    /// When the request entered the queue (queue-delay metric).
    pub enqueued: Instant,
}

/// A live, admitted session: one resumable task plus its timing marks.
struct ServeSession {
    job: Job,
    task: Box<dyn DecodeTask>,
    admitted: Instant,
    first_token: Option<Instant>,
}

/// The continuous-serving scheduler loop (the worker thread body).
pub(super) fn run_worker(
    engine: Box<dyn StepEngine + Send>,
    job_rx: mpsc::Receiver<Job>,
    stats: Arc<ServerStats>,
    stop: CancelFlag,
    max_sessions: usize,
    batched: bool,
) {
    let mut engine = engine;
    let max_sessions = max_sessions.max(1);
    let mut live: Vec<ServeSession> = Vec::new();
    while !stop.load(Ordering::Relaxed) {
        // Admission: fill free session slots from the queue.
        while live.len() < max_sessions {
            match job_rx.try_recv() {
                Ok(job) => admit(&mut engine, job, &mut live, &stats),
                Err(_) => break,
            }
        }
        if live.is_empty() {
            stats.active_sessions.store(0, Ordering::Relaxed);
            stats.kv_slots_in_use.store(0, Ordering::Relaxed);
            // Idle: block for work (bounded, so `stop` stays responsive).
            match job_rx.recv_timeout(Duration::from_millis(20)) {
                Ok(job) => admit(&mut engine, job, &mut live, &stats),
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
            continue;
        }
        round(&mut engine, &mut live, &stats, batched);
        let kv: usize = live.iter().map(|s| s.task.kv_slots_in_use()).sum();
        stats.active_sessions.store(live.len() as u64, Ordering::Relaxed);
        stats.kv_slots_in_use.store(kv as u64, Ordering::Relaxed);
    }
    // Dropping `live` drops every task → all session KV caches freed.
    drop(live);
    stats.active_sessions.store(0, Ordering::Relaxed);
    stats.kv_slots_in_use.store(0, Ordering::Relaxed);
}

/// Opens a task for `job` and admits it, or rejects it (KV headroom /
/// engine failure) with a typed error. Every dequeued job counts as a
/// request, matching the original FCFS accounting.
fn admit(
    engine: &mut Box<dyn StepEngine + Send>,
    job: Job,
    live: &mut Vec<ServeSession>,
    stats: &ServerStats,
) {
    stats.requests.fetch_add(1, Ordering::Relaxed);
    if job.cancelled.load(Ordering::Relaxed) {
        // Client vanished while the job sat in the queue.
        stats.cancelled.fetch_add(1, Ordering::Relaxed);
        return;
    }
    match engine.begin(&job.prompt, job.max_new) {
        Ok(task) => {
            if task.headroom() < job.prompt.len() + 1 {
                stats.rejected.fetch_add(1, Ordering::Relaxed);
                let message = format!(
                    "insufficient KV headroom for a {}-token prompt (headroom {})",
                    job.prompt.len(),
                    task.headroom()
                );
                let _ = job.reply.send(ServerEvent::Error { id: Some(job.id), message });
                // `task` drops here: its freshly allocated caches are freed.
            } else {
                let queue_s = job.enqueued.elapsed().as_secs_f64();
                stats
                    .recorder
                    .lock()
                    .unwrap()
                    .record_windowed("server.queue_delay_s", queue_s, STATS_WINDOW);
                live.push(ServeSession {
                    job,
                    task,
                    admitted: Instant::now(),
                    first_token: None,
                });
            }
        }
        Err(e) => {
            stats.errors.fetch_add(1, Ordering::Relaxed);
            let _ = job
                .reply
                .send(ServerEvent::Error { id: Some(job.id), message: format!("{e:#}") });
        }
    }
}

/// One scheduling round over every live session, removing sessions as
/// they cancel, finish, or fail.
///
/// In round-robin mode each task takes exactly one serial `step()` (the
/// time-sliced discipline). In batched mode the whole round goes through
/// [`StepEngine::step_batch`], letting engines with shared caches pack
/// the sessions' verification into one device call per round (DESIGN.md
/// §9) — outcomes still arrive one per session and are applied
/// identically.
fn round(
    engine: &mut Box<dyn StepEngine + Send>,
    live: &mut Vec<ServeSession>,
    stats: &ServerStats,
    batched: bool,
) {
    // Drop cancelled sessions first: frees their KV immediately and
    // keeps them out of this round's batch.
    let mut i = 0;
    while i < live.len() {
        if live[i].job.cancelled.load(Ordering::Relaxed) {
            drop(live.remove(i)); // frees the task's KV caches now
            stats.cancelled.fetch_add(1, Ordering::Relaxed);
        } else {
            i += 1;
        }
    }
    if live.is_empty() {
        return;
    }
    let outcomes: Vec<crate::Result<StepOutcome>> = if batched {
        let mut refs: Vec<&mut dyn DecodeTask> =
            live.iter_mut().map(|s| s.task.as_mut()).collect();
        engine.step_batch(&mut refs)
    } else {
        live.iter_mut().map(|s| s.task.step()).collect()
    };
    // Apply outcomes back-to-front so removals keep earlier indices valid.
    debug_assert_eq!(outcomes.len(), live.len());
    for (i, outcome) in outcomes.into_iter().enumerate().rev() {
        match outcome {
            Ok(out) => {
                let done = out.done();
                if !out.tokens.is_empty() {
                    let s = &mut live[i];
                    if s.first_token.is_none() {
                        s.first_token = Some(Instant::now());
                        let ttft = s.job.enqueued.elapsed().as_secs_f64();
                        stats
                            .recorder
                            .lock()
                            .unwrap()
                            .record_windowed("server.ttft_s", ttft, STATS_WINDOW);
                    }
                    if s.job.stream {
                        let ev = ServerEvent::Tokens { id: s.job.id, tokens: out.tokens };
                        if s.job.reply.send(ev).is_err() {
                            // Connection dropped between rounds.
                            drop(live.remove(i));
                            stats.cancelled.fetch_add(1, Ordering::Relaxed);
                            continue;
                        }
                    }
                }
                if done {
                    let s = live.remove(i);
                    finish_session(s, stats);
                }
            }
            Err(e) => {
                let s = live.remove(i);
                stats.errors.fetch_add(1, Ordering::Relaxed);
                let _ = s
                    .job
                    .reply
                    .send(ServerEvent::Error { id: Some(s.job.id), message: format!("{e:#}") });
            }
        }
    }
}

/// Completes a session: final metrics + the typed `done` event.
fn finish_session(s: ServeSession, stats: &ServerStats) {
    let ServeSession { job, task, admitted, first_token } = s;
    let g = task.finish();
    stats.tokens.fetch_add(g.tokens.len() as u64, Ordering::Relaxed);
    let active_s = admitted.elapsed().as_secs_f64();
    let tok_per_s = if active_s > 0.0 { g.tokens.len() as f64 / active_s } else { 0.0 };
    let queue_ms = admitted.duration_since(job.enqueued).as_secs_f64() * 1e3;
    let ttft_ms = first_token
        .map(|t| t.duration_since(job.enqueued).as_secs_f64() * 1e3)
        .unwrap_or(f64::NAN);
    stats
        .recorder
        .lock()
        .unwrap()
        .record_windowed("server.tok_per_s", tok_per_s, STATS_WINDOW);
    let aal = g.aal();
    let tpot_ms = g.tpot() * 1e3;
    let summary = DoneSummary {
        aal,
        tpot_ms,
        iterations: g.iterations,
        prefill_ms: g.prefill_seconds * 1e3,
        queue_ms,
        ttft_ms,
        tok_per_s,
        tokens: g.tokens,
    };
    let _ = job.reply.send(ServerEvent::Done { id: job.id, summary });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_serialize_with_ids_and_kind() {
        let ev = ServerEvent::Tokens { id: 7, tokens: vec![1, 2] };
        let j = ev.to_json();
        assert_eq!(j.str("event").unwrap(), "tokens");
        assert_eq!(j.u64("id").unwrap(), 7);
        let err = ServerEvent::Error { id: None, message: "boom".into() };
        assert_eq!(err.to_json().str("event").unwrap(), "error");
        assert!(err.to_json().get("id").is_none());
    }

    #[test]
    fn done_event_carries_serving_metrics() {
        let ev = ServerEvent::Done {
            id: 3,
            summary: DoneSummary {
                tokens: vec![9],
                aal: 2.0,
                tpot_ms: 1.5,
                iterations: 4,
                prefill_ms: 0.3,
                queue_ms: 12.0,
                ttft_ms: 20.0,
                tok_per_s: 800.0,
            },
        };
        let j = ev.to_json();
        assert_eq!(j.str("event").unwrap(), "done");
        assert!((j.f64("queue_ms").unwrap() - 12.0).abs() < 1e-9);
        assert!((j.f64("ttft_ms").unwrap() - 20.0).abs() < 1e-9);
        assert!((j.f64("tok_per_s").unwrap() - 800.0).abs() < 1e-9);
    }

    #[test]
    fn huge_ids_survive_the_wire_format() {
        let id = u64::MAX - 1;
        let ev = ServerEvent::Tokens { id, tokens: vec![] };
        let line = ev.to_json().to_string();
        let back = Json::parse(&line).unwrap();
        assert_eq!(back.u64("id").unwrap(), id);
    }
}
