//! Prefix-affinity request routing across a fleet of engine workers
//! (DESIGN.md §16).
//!
//! With N data-parallel [`EngineWorker`]s — each owning its own paged
//! pool and radix prefix trie — *where* a request lands decides whether
//! its prompt prefix is already resident. The [`Router`] therefore
//! places each request by **prefix-cache affinity**: the prompt's
//! cumulative chunk fingerprints ([`crate::kvcache::chunk_hashes`]) are
//! matched against a bounded per-worker summary of the prefixes that
//! worker has served (the SGLang-style cache-aware discipline), deepest
//! match wins, ties break toward the lighter worker. Prompts no worker
//! recognizes fall back to the least-loaded worker, tie-broken by a
//! deterministic prompt hash so cold clustered workloads spread instead
//! of piling onto worker 0. `--routing round-robin|least-loaded` swap
//! the whole policy for the classical baselines.
//!
//! Affinity creates skew by design — popular prefixes concentrate. The
//! counterweight is **work-stealing rebalance** ([`Router::rebalance`],
//! policy in [`crate::scheduler::steal_move`]): when a worker's backlog
//! exceeds a threshold, queued jobs migrate from the *back* of its
//! inbox to the least-loaded worker. Only never-admitted jobs are
//! stealable (the [`JobQueue`](super::worker::JobQueue) holds nothing
//! else), so a migration can never strand prefilled KV state.
//!
//! The router also mints each job's fleet-unique `uid` — client ids are
//! only unique per connection — and aggregates per-worker
//! [`ServerStats`] into one [`FleetSnapshot`].

use std::collections::HashSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::kvcache::{chunk_hashes, token_hash};
use crate::trace::{prom_header, prom_histogram, prom_sample, Name};
use crate::util::json::Json;

use super::worker::EngineWorker;
use super::{Job, ServeOpts, ServerEvent, ServerStats, StatsSnapshot};

/// Bits of a job uid holding the per-worker sequence number; the worker
/// namespace (index + 1) lives above them.
const UID_SEQ_BITS: u32 = 48;

/// Per-worker cap on remembered prefix fingerprints. A bound, not an
/// LRU: once a summary fills, new fingerprints are no longer recorded
/// (deterministic, unlike random replacement) — misses then degrade to
/// fallback placement, never to a wrong answer.
const SUMMARY_CAP: usize = 1 << 16;

/// Upper bound on jobs one [`Router::rebalance`] pass migrates, so a
/// mis-tuned threshold cannot spin the accept loop.
const MAX_STEALS_PER_PASS: usize = 64;

/// Request-placement policy (`--routing`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RoutingPolicy {
    /// Prefix-cache-affinity placement with least-loaded fallback (the
    /// default; DESIGN.md §16).
    #[default]
    Affinity,
    /// Strict rotation, blind to both cache state and load.
    RoundRobin,
    /// Always the lightest worker (queue + live sessions), blind to
    /// cache state.
    LeastLoaded,
}

impl RoutingPolicy {
    /// Stable CLI/config string form.
    pub fn as_str(&self) -> &'static str {
        match self {
            RoutingPolicy::Affinity => "affinity",
            RoutingPolicy::RoundRobin => "round-robin",
            RoutingPolicy::LeastLoaded => "least-loaded",
        }
    }

    /// Parses the CLI/config string form.
    pub fn from_str(s: &str) -> crate::Result<Self> {
        Ok(match s {
            "affinity" => RoutingPolicy::Affinity,
            "round-robin" => RoutingPolicy::RoundRobin,
            "least-loaded" => RoutingPolicy::LeastLoaded,
            _ => anyhow::bail!(
                "unknown routing policy '{s}' (expected affinity|round-robin|least-loaded)"
            ),
        })
    }
}

/// One placement decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Placement {
    /// Chosen worker index.
    pub worker: usize,
    /// The prompt matched the worker's prefix summary (affinity hit).
    pub affinity: bool,
    /// No summary matched: placed by the least-loaded fallback.
    pub fallback: bool,
    /// Whole prompt chunks the summary matched (0 on miss/other policy).
    pub depth: usize,
}

/// The pure placement core: a function of (prompt, per-worker loads,
/// accumulated summaries) with no clocks, threads, or randomness — the
/// property the routing-determinism tests pin (same wave + same seed ⇒
/// identical decisions).
pub struct Placer {
    policy: RoutingPolicy,
    chunk: usize,
    /// Per-worker set of cumulative prefix fingerprints this placer has
    /// routed there (the radix-trie path summary).
    summaries: Vec<HashSet<u64>>,
    rr: usize,
}

impl Placer {
    /// A placer for `workers` workers matching `chunk`-token prefix
    /// fingerprints (normally the prefix cache's block size).
    pub fn new(policy: RoutingPolicy, workers: usize, chunk: usize) -> Self {
        Self {
            policy,
            chunk: chunk.max(1),
            summaries: vec![HashSet::new(); workers.max(1)],
            rr: 0,
        }
    }

    /// Places one prompt given each worker's current load.
    pub fn place(&mut self, prompt: &[u32], loads: &[usize]) -> Placement {
        let n = self.summaries.len();
        debug_assert_eq!(loads.len(), n);
        match self.policy {
            RoutingPolicy::RoundRobin => {
                let worker = self.rr % n;
                self.rr += 1;
                Placement { worker, affinity: false, fallback: false, depth: 0 }
            }
            RoutingPolicy::LeastLoaded => {
                let worker = argmin_load(loads, 0, 1);
                Placement { worker, affinity: false, fallback: false, depth: 0 }
            }
            RoutingPolicy::Affinity => {
                let hashes = chunk_hashes(prompt, self.chunk);
                // Deepest summary match; ties toward (load, index).
                let mut best: Option<(usize, usize)> = None; // (depth, worker)
                for (w, summary) in self.summaries.iter().enumerate() {
                    let depth = hashes
                        .iter()
                        .rposition(|h| summary.contains(h))
                        .map(|i| i + 1)
                        .unwrap_or(0);
                    if depth == 0 {
                        continue;
                    }
                    let better = match best {
                        None => true,
                        Some((d, bw)) => {
                            depth > d
                                || (depth == d && (loads[w], w) < (loads[bw], bw))
                        }
                    };
                    if better {
                        best = Some((depth, w));
                    }
                }
                let p = match best {
                    Some((depth, worker)) => {
                        Placement { worker, affinity: true, fallback: false, depth }
                    }
                    None => {
                        // Cold prompt: least-loaded, with ties spread by
                        // a deterministic prompt hash (all-idle fleets
                        // would otherwise funnel every cold cluster onto
                        // worker 0).
                        let ties = loads.iter().filter(|&&l| l == *loads.iter().min().unwrap()).count();
                        let pick = (token_hash(prompt) % ties as u64) as usize;
                        let worker = argmin_load(loads, pick, ties);
                        Placement { worker, affinity: false, fallback: true, depth: 0 }
                    }
                };
                self.remember(p.worker, &hashes);
                p
            }
        }
    }

    /// Records `prompt`'s fingerprints against `worker` — used when a
    /// job migrates (work stealing), so the summary tracks where the
    /// prefix will actually be cached.
    pub fn note(&mut self, worker: usize, prompt: &[u32]) {
        let hashes = chunk_hashes(prompt, self.chunk);
        self.remember(worker, &hashes);
    }

    fn remember(&mut self, worker: usize, hashes: &[u64]) {
        let s = &mut self.summaries[worker];
        for &h in hashes {
            if s.len() >= SUMMARY_CAP {
                break;
            }
            s.insert(h);
        }
    }
}

/// The `skip`-th worker (0-based, modulo `ties`) among those sharing the
/// minimum load, scanning ascending indices — deterministic for both the
/// plain least-loaded argmin (`skip = 0`) and the hashed tie spread.
fn argmin_load(loads: &[usize], skip: usize, ties: usize) -> usize {
    let min = *loads.iter().min().expect("non-empty fleet");
    let mut seen = 0usize;
    let mut last = 0usize;
    for (w, &l) in loads.iter().enumerate() {
        if l == min {
            if seen == skip % ties.max(1) {
                return w;
            }
            seen += 1;
            last = w;
        }
    }
    last
}

/// Fleet-level statistics: every worker's [`StatsSnapshot`] plus one
/// merged view (summed counters/gauges, percentiles over the
/// concatenated per-worker series, `degrade_rung` as the fleet max) and
/// the routing counters.
#[derive(Debug, Clone)]
pub struct FleetSnapshot {
    /// Cross-worker aggregate (what the wire `stats` event leads with).
    pub merged: StatsSnapshot,
    /// Per-worker snapshots, indexed by worker id.
    pub workers: Vec<StatsSnapshot>,
    /// Placements that matched a worker's prefix summary.
    pub affinity_hits: u64,
    /// Affinity-policy placements that fell back to least-loaded.
    pub fallback_placements: u64,
    /// Jobs migrated by work-stealing rebalance.
    pub steals: u64,
}

impl FleetSnapshot {
    /// Wire form: the merged snapshot's fields at the top level (so
    /// single-worker stats consumers keep working unchanged), plus the
    /// routing counters and a `workers` array of per-worker objects.
    pub fn to_json(&self) -> Json {
        let mut j = self.merged.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("workers".into(), Json::Num(self.workers.len() as f64));
            m.insert(
                "worker_stats".into(),
                Json::Arr(self.workers.iter().map(|w| w.to_json()).collect()),
            );
            m.insert("affinity_hits".into(), Json::Num(self.affinity_hits as f64));
            m.insert(
                "fallback_placements".into(),
                Json::Num(self.fallback_placements as f64),
            );
            m.insert("steals".into(), Json::Num(self.steals as f64));
        }
        j
    }
}

/// The serving frontend's placement/rebalance/aggregation hub: owns the
/// fleet's [`EngineWorker`]s and is shared (`Arc`) by every connection
/// thread and the accept loop.
pub struct Router {
    workers: Vec<EngineWorker>,
    placer: Mutex<Placer>,
    steal_threshold: usize,
    /// Placements that matched a worker's prefix summary.
    pub affinity_hits: AtomicU64,
    /// Affinity placements that fell back to least-loaded.
    pub fallback_placements: AtomicU64,
    /// Jobs migrated off an over-threshold backlog.
    pub steals: AtomicU64,
    /// Per-worker uid sequence counters (the low half of minted uids).
    uid_seqs: Vec<AtomicU64>,
}

/// Receipt for a successfully routed job.
#[derive(Debug, Clone, Copy)]
pub struct Ticket {
    /// Worker the job was queued on.
    pub worker: usize,
    /// The fleet-unique id minted for the job.
    pub uid: u64,
}

impl Router {
    /// A router over `workers` (placement state sized to the fleet).
    pub fn new(workers: Vec<EngineWorker>, opts: &ServeOpts) -> Self {
        let n = workers.len();
        Self {
            placer: Mutex::new(Placer::new(opts.routing, n, opts.affinity_chunk)),
            steal_threshold: opts.steal_threshold.max(1),
            affinity_hits: AtomicU64::new(0),
            fallback_placements: AtomicU64::new(0),
            steals: AtomicU64::new(0),
            uid_seqs: (0..n).map(|_| AtomicU64::new(0)).collect(),
            workers,
        }
    }

    /// The fleet, indexed by worker id.
    pub fn workers(&self) -> &[EngineWorker] {
        &self.workers
    }

    /// Mints a fleet-unique job id in `worker`'s namespace:
    /// `(worker + 1) << 48 | seq`. Worker indices are far below 2^16 and
    /// a u48 sequence outlives any realistic process, so uids never
    /// collide across workers, reconnects, or restarts of the sequence's
    /// owner connection — the regression the per-`Server` minting had.
    pub fn mint_uid(&self, worker: usize) -> u64 {
        let seq = self.uid_seqs[worker].fetch_add(1, Ordering::Relaxed);
        ((worker as u64 + 1) << UID_SEQ_BITS) | (seq & ((1 << UID_SEQ_BITS) - 1))
    }

    fn loads(&self) -> Vec<usize> {
        self.workers.iter().map(|w| w.load()).collect()
    }

    /// Routes one job: place, mint its uid, enqueue. A full target queue
    /// spills to the lightest worker with room; only a fleet-wide-full
    /// (or shutting-down) state hands the job back for a `queue full`
    /// rejection.
    pub fn submit(&self, mut job: Job) -> Result<Ticket, Job> {
        let loads = self.loads();
        let p = self.placer.lock().unwrap().place(&job.prompt, &loads);
        if p.affinity {
            self.affinity_hits.fetch_add(1, Ordering::Relaxed);
        }
        if p.fallback {
            self.fallback_placements.fetch_add(1, Ordering::Relaxed);
        }
        let uid = self.mint_uid(p.worker);
        job.uid = uid;
        job = match self.workers[p.worker].queue().try_push(job) {
            Ok(()) => {
                // Placement event into the *target* worker's ring
                // (DESIGN.md §17): arg 1 = affinity hit, 0 = fallback.
                self.workers[p.worker]
                    .tracer
                    .instant(Name::Place, uid, i64::from(p.affinity));
                return Ok(Ticket { worker: p.worker, uid });
            }
            Err(j) => j,
        };
        // Spill: lightest other workers first, deterministic on ties.
        let mut order: Vec<usize> =
            (0..self.workers.len()).filter(|&w| w != p.worker).collect();
        order.sort_by_key(|&w| (loads[w], w));
        for w in order {
            let uid = self.mint_uid(w);
            job.uid = uid;
            job = match self.workers[w].queue().try_push(job) {
                Ok(()) => {
                    self.workers[w].tracer.instant(Name::Place, uid, 0);
                    return Ok(Ticket { worker: w, uid });
                }
                Err(j) => j,
            };
        }
        Err(job)
    }

    /// One work-stealing pass (DESIGN.md §16): while some backlog
    /// exceeds the threshold and a strictly lighter destination exists
    /// ([`crate::scheduler::steal_move`]), migrate the *most recently
    /// queued* job — never anything admitted or prefilled, by
    /// [`JobQueue`](super::worker::JobQueue) construction. Returns the
    /// number of jobs moved. Called from the accept loop's poll tick.
    pub fn rebalance(&self) -> usize {
        if self.workers.len() < 2 {
            return 0;
        }
        let mut moved = 0;
        while moved < MAX_STEALS_PER_PASS {
            let backlogs: Vec<usize> = self.workers.iter().map(|w| w.backlog()).collect();
            let loads = self.loads();
            let Some((src, dst)) =
                crate::scheduler::steal_move(&backlogs, &loads, self.steal_threshold)
            else {
                break;
            };
            let Some(job) = self.workers[src].queue().steal_back() else {
                break;
            };
            // The prefix will now be cached on `dst`: update the summary
            // so followers route after the migrated job, not before it.
            // (The stolen job keeps its minted uid — uniqueness, not the
            // namespace, is the contract.)
            self.placer.lock().unwrap().note(dst, &job.prompt);
            let uid = job.uid;
            match self.workers[dst].queue().try_push(job) {
                Ok(()) => {
                    moved += 1;
                    self.steals.fetch_add(1, Ordering::Relaxed);
                    // Migration event into the *destination* worker's
                    // ring; arg = the source worker it was stolen from.
                    self.workers[dst].tracer.instant(Name::Steal, uid, src as i64);
                }
                Err(job) => {
                    // Destination refused (filled up / closing): put the
                    // job back; if even that fails the fleet is shutting
                    // down — reject rather than strand the client.
                    if let Err(job) = self.workers[src].queue().try_push(job) {
                        let _ = job.reply.send(ServerEvent::Error {
                            id: Some(job.id),
                            message: "queue full".into(),
                        });
                    }
                    break;
                }
            }
        }
        moved
    }

    /// Aggregates every worker's stats into one [`FleetSnapshot`].
    pub fn fleet_snapshot(&self) -> FleetSnapshot {
        let workers: Vec<StatsSnapshot> =
            self.workers.iter().map(|w| w.stats.snapshot()).collect();
        let acc = ServerStats::default();
        for w in &self.workers {
            acc.merge_from(&w.stats);
        }
        FleetSnapshot {
            merged: acc.snapshot(),
            workers,
            affinity_hits: self.affinity_hits.load(Ordering::Relaxed),
            fallback_placements: self.fallback_placements.load(Ordering::Relaxed),
            steals: self.steals.load(Ordering::Relaxed),
        }
    }

    /// Renders the fleet's statistics in Prometheus text exposition
    /// format (DESIGN.md §17): every [`ServerStats`] counter and gauge as
    /// per-worker samples plus a `worker="fleet"` aggregate, the routing
    /// counters, and latency histograms bucketed
    /// ([`crate::trace::LATENCY_BUCKETS_S`]) from each worker's windowed
    /// recorder series. The output passes
    /// [`crate::trace::validate_prometheus`] by construction (pinned by a
    /// unit test in the server module).
    pub fn metrics_text(&self) -> String {
        let snap = self.fleet_snapshot();
        // One row per metric: (name, type, help, value-extractor). The
        // same extractor runs on the merged snapshot and on every
        // per-worker snapshot, so the fleet and worker samples can never
        // drift apart.
        type Row = (&'static str, &'static str, &'static str, fn(&StatsSnapshot) -> f64);
        const ROWS: &[Row] = &[
            ("ygg_requests_total", "counter",
             "Requests dequeued (admitted or rejected).", |s| s.requests as f64),
            ("ygg_tokens_total", "counter",
             "Tokens committed across completed generations.", |s| s.tokens as f64),
            ("ygg_errors_total", "counter",
             "Request-level failures.", |s| s.errors as f64),
            ("ygg_cancelled_total", "counter",
             "Sessions dropped on client disconnect.", |s| s.cancelled as f64),
            ("ygg_rejected_total", "counter",
             "Requests refused by KV-headroom admission control.", |s| s.rejected as f64),
            ("ygg_preemptions_total", "counter",
             "Sessions preempted under paged pool exhaustion.", |s| s.preemptions as f64),
            ("ygg_resumes_total", "counter",
             "Preempted sessions successfully re-admitted.", |s| s.resumes as f64),
            ("ygg_active_sessions", "gauge",
             "Live sessions after the last scheduling round.", |s| s.active_sessions as f64),
            ("ygg_peak_sessions", "gauge",
             "High-water mark of concurrently admitted sessions.", |s| s.peak_sessions as f64),
            ("ygg_kv_slots_in_use", "gauge",
             "KV slots held across live sessions.", |s| s.kv_slots_in_use as f64),
            ("ygg_blocks_in_use", "gauge",
             "Shared-pool blocks currently leased.", |s| s.blocks_in_use as f64),
            ("ygg_blocks_total", "gauge",
             "Total shared-pool blocks (paged layout only).", |s| s.blocks_total as f64),
            ("ygg_prefix_lookups_total", "counter",
             "Prefix-cache lookups.", |s| s.prefix_lookups as f64),
            ("ygg_prefix_hits_total", "counter",
             "Prefix-cache lookups that matched a cached block.", |s| s.prefix_hits as f64),
            ("ygg_prefix_tokens_reused_total", "counter",
             "Prompt tokens served from the prefix cache.", |s| s.prefix_tokens_reused as f64),
            ("ygg_prefix_evictions_total", "counter",
             "Cached blocks reclaimed by LRU eviction.", |s| s.prefix_evictions as f64),
            ("ygg_prefix_cached_blocks", "gauge",
             "Blocks currently held by the prefix trie.", |s| s.prefix_cached_blocks as f64),
            ("ygg_prefill_chunks_total", "counter",
             "Prefill chunks stepped under chunked prefill.", |s| s.prefill_chunks as f64),
            ("ygg_degraded_rounds_total", "counter",
             "Scheduling rounds run under a non-zero degradation rung.",
             |s| s.degraded_rounds as f64),
            ("ygg_slo_violations_total", "counter",
             "Latency-class inter-token gaps beyond the SLO target.",
             |s| s.slo_violations as f64),
            ("ygg_degrade_rung", "gauge",
             "Current overload-degradation rung (0 = no pressure).", |s| s.degrade_rung as f64),
            ("ygg_alloc_budget_rows", "gauge",
             "Verify rows the round allocator granted in the last batched round.",
             |s| s.alloc_budget_total as f64),
            ("ygg_alloc_rounds_total", "counter",
             "Rounds the global allocator resolved budgets for.", |s| s.alloc_rounds as f64),
        ];
        let mut out = String::with_capacity(1 << 14);
        for &(name, kind, help, get) in ROWS {
            prom_header(&mut out, name, kind, help);
            for (w, ws) in snap.workers.iter().enumerate() {
                let wl = w.to_string();
                prom_sample(&mut out, name, &[("worker", &wl)], get(ws));
            }
            prom_sample(&mut out, name, &[("worker", "fleet")], get(&snap.merged));
        }
        // Routing counters (fleet-level by nature: the router owns them).
        for (name, help, v) in [
            ("ygg_affinity_hits_total",
             "Placements that matched a worker's prefix summary.", snap.affinity_hits),
            ("ygg_fallback_placements_total",
             "Affinity placements that fell back to least-loaded.", snap.fallback_placements),
            ("ygg_steals_total",
             "Jobs migrated by work-stealing rebalance.", snap.steals),
        ] {
            prom_header(&mut out, name, "counter", help);
            prom_sample(&mut out, name, &[], v as f64);
        }
        // Latency histograms from the windowed per-request series: the
        // fleet variant buckets the *concatenated* per-worker samples,
        // the same pooled-not-averaged discipline as the merged
        // percentiles (windowed, so recent traffic — not all history).
        for (name, series, help) in [
            ("ygg_ttft_seconds", "server.ttft_s",
             "Enqueue to first committed token, seconds."),
            ("ygg_itl_latency_seconds", "server.itl_s.latency",
             "Latency-class inter-token latency, seconds."),
            ("ygg_itl_throughput_seconds", "server.itl_s.throughput",
             "Throughput-class inter-token latency, seconds."),
            ("ygg_queue_delay_seconds", "server.queue_delay_s",
             "Queueing delay before admission, seconds."),
        ] {
            prom_header(&mut out, name, "histogram", help);
            let mut fleet: Vec<f64> = Vec::new();
            for (w, worker) in self.workers.iter().enumerate() {
                let samples: Vec<f64> = worker
                    .stats
                    .recorder
                    .lock()
                    .unwrap()
                    .get(series)
                    .map(|s| s.samples().to_vec())
                    .unwrap_or_default();
                let wl = w.to_string();
                prom_histogram(&mut out, name, &[("worker", &wl)], &samples);
                fleet.extend_from_slice(&samples);
            }
            prom_histogram(&mut out, name, &[("worker", "fleet")], &fleet);
        }
        out
    }

    /// Stops and joins every worker (idempotent).
    pub fn shutdown(&self) {
        for w in &self.workers {
            w.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::{EchoEngine, SloClass};
    use super::*;
    use std::sync::atomic::AtomicBool;
    use std::sync::{mpsc, Arc};

    fn policy_roundtrip(p: RoutingPolicy) {
        assert_eq!(RoutingPolicy::from_str(p.as_str()).unwrap(), p);
    }

    #[test]
    fn routing_policy_strings_roundtrip() {
        policy_roundtrip(RoutingPolicy::Affinity);
        policy_roundtrip(RoutingPolicy::RoundRobin);
        policy_roundtrip(RoutingPolicy::LeastLoaded);
        assert!(RoutingPolicy::from_str("bogus").is_err());
    }

    /// A clustered-prefix wave: `groups` system prompts of `prefix_len`
    /// tokens, each followed by a unique per-request tail.
    fn clustered_wave(groups: usize, per_group: usize, prefix_len: usize) -> Vec<Vec<u32>> {
        let mut wave = Vec::new();
        for g in 0..groups {
            for c in 0..per_group {
                let mut p: Vec<u32> = (0..prefix_len as u32)
                    .map(|i| 1000 * (g as u32 + 1) + i)
                    .collect();
                p.push(7_000 + (g * per_group + c) as u32);
                wave.push(p);
            }
        }
        wave
    }

    #[test]
    fn affinity_follows_the_seeded_prefix() {
        let mut placer = Placer::new(RoutingPolicy::Affinity, 4, 16);
        let wave = clustered_wave(4, 4, 32);
        let loads = vec![0usize; 4];
        // First client of each group lands somewhere (fallback)…
        let seeds: Vec<Placement> =
            (0..4).map(|g| placer.place(&wave[g * 4], &loads)).collect();
        for s in &seeds {
            assert!(s.fallback && !s.affinity);
        }
        // …and every later same-group client follows it, regardless of
        // load skew.
        let skewed = vec![9, 9, 9, 9];
        for g in 0..4 {
            for c in 1..4 {
                let p = placer.place(&wave[g * 4 + c], &skewed);
                assert!(p.affinity, "group {g} client {c} missed");
                assert_eq!(p.worker, seeds[g].worker);
                assert_eq!(p.depth, 2, "two whole 16-token chunks matched");
            }
        }
    }

    /// Satellite: same wave + same seed ⇒ identical placement decisions
    /// (the placer is a pure function of its inputs — no clocks, no
    /// thread timing, no randomness).
    #[test]
    fn affinity_placement_is_deterministic_across_runs() {
        let run = |seed: u64| -> Vec<Placement> {
            let mut placer = Placer::new(RoutingPolicy::Affinity, 4, 16);
            // Seeded LCG wave: random group order + random load vectors.
            let mut state = seed;
            let mut next = move || {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                (state >> 33) as usize
            };
            let wave = clustered_wave(4, 4, 32);
            (0..64)
                .map(|_| {
                    let prompt = &wave[next() % wave.len()];
                    let loads: Vec<usize> = (0..4).map(|_| next() % 8).collect();
                    placer.place(prompt, &loads)
                })
                .collect()
        };
        assert_eq!(run(42), run(42), "same seed must replay identically");
        assert_eq!(run(7), run(7));
    }

    #[test]
    fn cold_fallback_spreads_clusters_across_idle_workers() {
        let mut placer = Placer::new(RoutingPolicy::Affinity, 4, 16);
        let loads = vec![0usize; 4];
        let wave = clustered_wave(4, 1, 32);
        let picked: HashSet<usize> =
            wave.iter().map(|p| placer.place(p, &loads).worker).collect();
        assert!(
            picked.len() >= 2,
            "4 distinct cold prefixes funneled onto one worker: {picked:?}"
        );
    }

    #[test]
    fn round_robin_and_least_loaded_ignore_prefixes() {
        let mut rr = Placer::new(RoutingPolicy::RoundRobin, 3, 16);
        let loads = vec![5, 0, 5];
        let seq: Vec<usize> =
            (0..6).map(|_| rr.place(&[1, 2, 3], &loads).worker).collect();
        assert_eq!(seq, vec![0, 1, 2, 0, 1, 2]);
        let mut ll = Placer::new(RoutingPolicy::LeastLoaded, 3, 16);
        assert_eq!(ll.place(&[1, 2, 3], &loads).worker, 1);
        assert_eq!(ll.place(&[1, 2, 3], &[2, 2, 2]).worker, 0, "ties → lowest index");
    }

    fn echo_router(workers: usize, opts: &ServeOpts) -> Router {
        let fleet: Vec<EngineWorker> = (0..workers)
            .map(|i| EngineWorker::spawn(i, Box::new(EchoEngine), opts).unwrap())
            .collect();
        Router::new(fleet, opts)
    }

    fn test_job(id: u64, prompt: Vec<u32>) -> (Job, mpsc::Receiver<ServerEvent>) {
        let (tx, rx) = mpsc::channel();
        let cancel = Arc::new(AtomicBool::new(false));
        (Job::new(id, prompt, 2, SloClass::Latency, tx, false, cancel), rx)
    }

    /// Satellite regression: client ids are only unique per connection —
    /// reconnecting clients (and distinct connections) may all send
    /// `id: 0`. The router's minted uids must stay unique fleet-wide
    /// anyway, namespaced by worker.
    #[test]
    fn uids_stay_unique_across_reconnects_and_workers() {
        let opts = ServeOpts { max_queue: 64, ..ServeOpts::default() };
        let router = echo_router(3, &opts);
        let mut seen = HashSet::new();
        let mut rxs = Vec::new();
        for round in 0..30 {
            // Every "reconnect" reuses the same client id on a fresh
            // reply channel, with rotating prompts to hit every worker.
            let (job, rx) = test_job(0, vec![round % 3 + 1; 40]);
            rxs.push(rx);
            let Ok(t) = router.submit(job) else { panic!("fleet has queue room") };
            assert!(t.worker < 3);
            assert_eq!(t.uid >> UID_SEQ_BITS, t.worker as u64 + 1, "worker namespace");
            assert!(seen.insert(t.uid), "uid {:#x} collided", t.uid);
        }
        // Direct namespace check: same sequence number, different
        // workers, still distinct.
        assert_ne!(router.mint_uid(0), router.mint_uid(1));
        router.shutdown();
    }

    /// The wire `{"metrics": true}` body is rendered here: it must be
    /// parseable Prometheus text exposition with per-worker and fleet
    /// label variants for every metric family.
    #[test]
    fn metrics_text_is_valid_prometheus_with_worker_and_fleet_labels() {
        let opts = ServeOpts { max_queue: 8, ..ServeOpts::default() };
        let router = echo_router(2, &opts);
        let (job, rx) = test_job(1, vec![8, 9]);
        router.workers()[0].queue().try_push(job).ok().unwrap();
        loop {
            match rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap() {
                ServerEvent::Done { .. } => break,
                ServerEvent::Error { message, .. } => panic!("error: {message}"),
                _ => {}
            }
        }
        let text = router.metrics_text();
        crate::trace::validate_prometheus(&text).unwrap();
        assert!(text.contains(r#"ygg_requests_total{worker="0"} 1"#), "{text}");
        assert!(text.contains(r#"ygg_requests_total{worker="1"} 0"#));
        assert!(text.contains(r#"ygg_requests_total{worker="fleet"} 1"#));
        assert!(text.contains("# TYPE ygg_ttft_seconds histogram"));
        assert!(text.contains(r#"le="+Inf""#));
        assert!(text.contains("ygg_ttft_seconds_count"));
        assert!(text.contains("ygg_steals_total 0"));
        router.shutdown();
    }

    #[test]
    fn fleet_snapshot_merges_counters_and_series() {
        let opts = ServeOpts { max_queue: 8, ..ServeOpts::default() };
        let router = echo_router(2, &opts);
        // Complete one request per worker (round-level determinism not
        // needed — just traffic on both).
        for w in 0..2 {
            let (job, rx) = test_job(w as u64, vec![10 + w as u32, 11]);
            router.workers()[w].queue().try_push(job).ok().unwrap();
            loop {
                match rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap() {
                    ServerEvent::Done { .. } => break,
                    ServerEvent::Error { message, .. } => panic!("error: {message}"),
                    _ => {}
                }
            }
        }
        let snap = router.fleet_snapshot();
        assert_eq!(snap.workers.len(), 2);
        assert_eq!(snap.merged.requests, 2, "summed across workers");
        assert_eq!(snap.merged.tokens, 4);
        assert_eq!(
            snap.merged.requests,
            snap.workers.iter().map(|w| w.requests).sum::<u64>()
        );
        // Merged percentiles come from the concatenated series: two
        // queue-delay samples total.
        let j = snap.to_json();
        assert_eq!(j.u64("requests").unwrap(), 2);
        assert_eq!(j.u64("steals").unwrap(), 0);
        assert_eq!(j.u64("workers").unwrap(), 2);
        assert_eq!(j.arr("worker_stats").unwrap().len(), 2);
        router.shutdown();
    }
}
