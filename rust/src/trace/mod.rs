//! Request-lifecycle tracing (DESIGN.md §17): a per-worker fixed-capacity
//! **flight recorder** of structured [`TraceEvent`]s plus two export
//! paths — Chrome trace-event JSON (Perfetto-loadable, `--trace-out`) and
//! a Prometheus text-format exposition (the `{"metrics": true}` wire
//! request).
//!
//! ## Why a ring buffer and not a log
//!
//! The serving round loop is allocation-audited (`tests/alloc_steady_state.rs`
//! holds it to **zero** steady-state heap allocations), so the recorder
//! cannot format strings, grow vectors, or touch a channel on the hot
//! path. Instead every event is a fixed-size [`Copy`] record — an interned
//! [`Name`] id, a [`Kind`], and five integers — written into a ring of
//! preallocated slots under a brief mutex. Pushing is O(1), alloc-free,
//! and oldest events are overwritten silently; the ring is a *flight
//! recorder*, sized (`--trace-ring`) to hold the last few seconds of
//! decisions so a post-mortem (degradation escalation, preemption) can
//! dump the recent window without having paid for unbounded history.
//!
//! ## Event schema
//!
//! Every event is stamped `(worker, request uid, round, span id)`:
//!
//! * `worker` — fleet-wide worker index (one tracer per worker).
//! * `uid` — the request uid minted by the router (`(worker+1) << 48 | seq`),
//!   or 0 for round-wide events (stage spans cover the whole batch).
//! * `round` — the worker's scheduling-round counter, set once per round
//!   by the scheduler; engine-side stage spans inherit it.
//! * `span` — pairs a [`Kind::SpanBegin`] with its [`Kind::SpanEnd`];
//!   0 for instant events.
//!
//! `arg` carries one event-specific integer: tokens reused on
//! [`Name::PrefixAttach`], pending prefill on [`Name::PrefillChunk`], granted
//! budget on [`Name::AllocGrant`], the new rung on [`Name::RungChange`].

use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::Json;

/// Default flight-recorder capacity (events per worker, `--trace-ring`).
pub const DEFAULT_RING: usize = 8192;

/// Rounds of history auto-dumped on degradation escalation / preemption.
pub const DUMP_ROUNDS: u64 = 4;

/// Interned event-name ids. The enum *is* the intern table: recording
/// stores the discriminant, exporters call [`Name::as_str`] off the hot
/// path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Name {
    /// Whole-request span: opened at admission, closed at completion,
    /// error, cancel, or shutdown-abort.
    Request,
    /// A request was admitted into the live set (instant).
    Admit,
    /// A request was rejected at admission (instant; arg = queue depth).
    Reject,
    /// Router placement decision (instant; arg = 1 for an affinity hit,
    /// 0 for a load-based fallback).
    Place,
    /// Work-stealing migration into this worker (instant; arg = source
    /// worker).
    Steal,
    /// Prefix-cache attach at admission (instant; arg = prompt tokens
    /// reused from the radix trie).
    PrefixAttach,
    /// One chunked-prefill slice (instant; arg = uncached prompt tokens
    /// still pending after the slice — 0 marks the final chunk).
    PrefillChunk,
    /// One scheduling round (span; uid 0).
    Round,
    /// Deferred-head draft stage (span; uid 0).
    HeadDraft,
    /// Per-level tree-draft stage (span; uid 0).
    TreeDraft,
    /// CPU mask/pack build stage (span; uid 0).
    CpuBuild,
    /// Packed tree-verification stage (span; uid 0).
    Verify,
    /// Arena acceptance-walk stage (span; uid 0).
    AcceptWalk,
    /// Per-session verify-budget grant (instant; arg = granted rows).
    AllocGrant,
    /// Degradation-ladder rung transition (instant; arg = new rung).
    RungChange,
    /// A session was preempted to the resume deque (instant).
    Preempt,
    /// A preempted session resumed (instant; arg = resume count).
    Resume,
    /// Client disconnect observed mid-stream (instant).
    Disconnect,
    /// A request finished and its summary was sent (instant; arg =
    /// tokens generated).
    Done,
}

impl Name {
    /// Static display name (also the Chrome trace-event `name`).
    pub fn as_str(self) -> &'static str {
        match self {
            Name::Request => "request",
            Name::Admit => "admit",
            Name::Reject => "reject",
            Name::Place => "place",
            Name::Steal => "steal",
            Name::PrefixAttach => "prefix_attach",
            Name::PrefillChunk => "prefill_chunk",
            Name::Round => "round",
            Name::HeadDraft => "stage.head_draft",
            Name::TreeDraft => "stage.tree_draft",
            Name::CpuBuild => "stage.cpu_build",
            Name::Verify => "stage.verify",
            Name::AcceptWalk => "stage.accept_walk",
            Name::AllocGrant => "alloc_grant",
            Name::RungChange => "rung_change",
            Name::Preempt => "preempt",
            Name::Resume => "resume",
            Name::Disconnect => "disconnect",
            Name::Done => "done",
        }
    }
}

/// Event kind: paired span edges or a standalone instant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Kind {
    /// Opens a span; paired with the [`Kind::SpanEnd`] carrying the same
    /// span id.
    SpanBegin,
    /// Closes the span opened with the same span id.
    SpanEnd,
    /// A point event (no duration).
    Instant,
}

/// One fixed-size trace record (see the module docs for the schema).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceEvent {
    /// Interned event name.
    pub name: Name,
    /// Span edge or instant.
    pub kind: Kind,
    /// Fleet-wide worker index.
    pub worker: u16,
    /// Request uid (0 for round-wide events).
    pub uid: u64,
    /// Scheduling round the event occurred in.
    pub round: u64,
    /// Span pairing id (0 for instants).
    pub span: u32,
    /// Microseconds since the tracer's epoch.
    pub t_us: u64,
    /// Event-specific argument (see [`Name`]).
    pub arg: i64,
}

impl TraceEvent {
    /// Placeholder filling preallocated ring slots; never observable
    /// (the ring tracks its valid length separately).
    pub const EMPTY: TraceEvent = TraceEvent {
        name: Name::Request,
        kind: Kind::Instant,
        worker: 0,
        uid: 0,
        round: 0,
        span: 0,
        t_us: 0,
        arg: 0,
    };
}

/// Fixed-capacity ring of [`TraceEvent`]s. All slots are preallocated at
/// construction; [`FlightRecorder::push`] never touches the heap.
pub struct FlightRecorder {
    buf: Vec<TraceEvent>,
    /// Next slot to overwrite.
    next: usize,
    /// Valid events (≤ capacity).
    len: usize,
    /// Events ever pushed (monotone; `total - len` were overwritten).
    total: u64,
}

impl FlightRecorder {
    /// A recorder holding at most `capacity` events (0 disables it).
    pub fn new(capacity: usize) -> Self {
        Self { buf: vec![TraceEvent::EMPTY; capacity], next: 0, len: 0, total: 0 }
    }

    /// Appends one event, overwriting the oldest once full. O(1) and
    /// allocation-free; a no-op at capacity 0.
    pub fn push(&mut self, ev: TraceEvent) {
        let cap = self.buf.len();
        if cap == 0 {
            return;
        }
        self.buf[self.next] = ev;
        self.next = (self.next + 1) % cap;
        self.len = (self.len + 1).min(cap);
        self.total += 1;
    }

    /// Slot capacity.
    pub fn capacity(&self) -> usize {
        self.buf.len()
    }

    /// Valid (retained) events.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when nothing has been recorded (or capacity is 0).
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Events ever pushed, including overwritten ones.
    pub fn total(&self) -> u64 {
        self.total
    }

    /// The retained events, oldest first (allocates; export path only).
    pub fn to_vec(&self) -> Vec<TraceEvent> {
        let cap = self.buf.len();
        let mut out = Vec::with_capacity(self.len);
        let start = (self.next + cap - self.len) % cap.max(1);
        for i in 0..self.len {
            out.push(self.buf[(start + i) % cap]);
        }
        out
    }
}

/// Per-worker tracing handle: the flight-recorder ring plus the round
/// counter and span-id mint. Shared (`Arc`) between the scheduler loop,
/// the engine (stage spans), and the router (placement/steal events).
pub struct Tracer {
    worker: u16,
    epoch: Instant,
    ring: Mutex<FlightRecorder>,
    round: AtomicU64,
    next_span: AtomicU32,
}

impl Tracer {
    /// A tracer for `worker` retaining the last `capacity` events.
    /// Capacity 0 disables recording entirely (pushes return before
    /// taking the lock).
    pub fn new(worker: usize, capacity: usize) -> Self {
        Self {
            worker: worker as u16,
            epoch: Instant::now(),
            ring: Mutex::new(FlightRecorder::new(capacity)),
            round: AtomicU64::new(0),
            next_span: AtomicU32::new(1),
        }
    }

    /// The worker index this tracer stamps on every event.
    pub fn worker(&self) -> usize {
        self.worker as usize
    }

    /// True when the ring has slots (capacity > 0).
    pub fn enabled(&self) -> bool {
        self.ring.lock().unwrap().capacity() > 0
    }

    /// Sets the scheduling-round stamp for subsequent events. Called once
    /// per round by the scheduler; engine-side stage spans inherit it.
    pub fn set_round(&self, round: u64) {
        self.round.store(round, Ordering::Relaxed);
    }

    /// The current scheduling-round stamp.
    pub fn current_round(&self) -> u64 {
        self.round.load(Ordering::Relaxed)
    }

    /// Total events ever pushed (monotone across overwrites).
    pub fn pushed(&self) -> u64 {
        self.ring.lock().unwrap().total()
    }

    fn push(&self, name: Name, kind: Kind, uid: u64, span: u32, arg: i64) {
        let ev = TraceEvent {
            name,
            kind,
            worker: self.worker,
            uid,
            round: self.round.load(Ordering::Relaxed),
            span,
            t_us: self.epoch.elapsed().as_micros() as u64,
            arg,
        };
        let mut ring = self.ring.lock().unwrap();
        ring.push(ev);
    }

    /// Records an instant event.
    pub fn instant(&self, name: Name, uid: u64, arg: i64) {
        self.push(name, Kind::Instant, uid, 0, arg);
    }

    /// Opens a span and returns its pairing id for [`Tracer::end`].
    pub fn begin(&self, name: Name, uid: u64) -> u32 {
        let span = self.next_span.fetch_add(1, Ordering::Relaxed);
        self.push(name, Kind::SpanBegin, uid, span, 0);
        span
    }

    /// Closes the span opened by [`Tracer::begin`].
    pub fn end(&self, name: Name, uid: u64, span: u32) {
        self.push(name, Kind::SpanEnd, uid, span, 0);
    }

    /// Closes a span carrying a result argument (e.g. accepted tokens).
    pub fn end_with(&self, name: Name, uid: u64, span: u32, arg: i64) {
        self.push(name, Kind::SpanEnd, uid, span, arg);
    }

    /// Snapshot of the retained events, oldest first (allocates).
    pub fn events(&self) -> Vec<TraceEvent> {
        self.ring.lock().unwrap().to_vec()
    }

    /// Retained events from the most recent `rounds` scheduling rounds —
    /// the auto-dump window on escalation / preemption (allocates).
    pub fn window(&self, rounds: u64) -> Vec<TraceEvent> {
        let cur = self.current_round();
        let lo = cur.saturating_sub(rounds.saturating_sub(1));
        self.events().into_iter().filter(|e| e.round >= lo).collect()
    }
}

/// One-line rendering of a dumped flight-recorder window for the log
/// stream (post-mortem context on escalation / preemption).
pub fn format_window(events: &[TraceEvent]) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    for e in events {
        let kind = match e.kind {
            Kind::SpanBegin => "B",
            Kind::SpanEnd => "E",
            Kind::Instant => "i",
        };
        let _ = writeln!(
            out,
            "  [t={}us w{} uid={} r{}] {} {} span={} arg={}",
            e.t_us,
            e.worker,
            e.uid,
            e.round,
            kind,
            e.name.as_str(),
            e.span,
            e.arg
        );
    }
    out
}

// ---------------------------------------------------------------- Chrome

/// Renders events as a Chrome trace-event JSON document (the
/// `{"traceEvents": [...]}` object form; loadable in Perfetto /
/// `chrome://tracing`). Spans become `B`/`E` pairs nested per worker
/// track (`pid` = worker, `tid` = request uid, 0 for round-wide), and
/// instants become thread-scoped `i` events. Each event's args carry the
/// full `(uid, round, span, arg)` stamp, so the JSON round-trips the
/// schema losslessly even where `tid` truncates the uid to 32 bits.
pub fn chrome_trace(events: &[TraceEvent]) -> Json {
    let evs: Vec<Json> = events
        .iter()
        .map(|e| {
            let ph = match e.kind {
                Kind::SpanBegin => "B",
                Kind::SpanEnd => "E",
                Kind::Instant => "i",
            };
            let mut pairs = vec![
                ("name", Json::Str(e.name.as_str().to_string())),
                ("ph", Json::Str(ph.to_string())),
                ("pid", Json::Num(e.worker as f64)),
                ("tid", Json::Num((e.uid & 0xffff_ffff) as f64)),
                ("ts", Json::Num(e.t_us as f64)),
                (
                    "args",
                    Json::obj(vec![
                        ("uid", Json::from_u64(e.uid)),
                        ("round", Json::from_u64(e.round)),
                        ("span", Json::Num(e.span as f64)),
                        ("arg", Json::Num(e.arg as f64)),
                    ]),
                ),
            ];
            if matches!(e.kind, Kind::Instant) {
                // Thread-scoped instant (draws at the event's track).
                pairs.push(("s", Json::Str("t".to_string())));
            }
            Json::obj(pairs)
        })
        .collect();
    Json::obj(vec![
        ("traceEvents", Json::Arr(evs)),
        ("displayTimeUnit", Json::Str("ms".to_string())),
    ])
}

// ------------------------------------------------------------ Prometheus

/// Histogram bucket upper bounds (seconds) for latency expositions —
/// log-spaced from 0.5 ms to 2.5 s; `+Inf` is implicit.
pub const LATENCY_BUCKETS_S: [f64; 12] =
    [0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5];

fn write_labels(out: &mut String, labels: &[(&str, &str)]) {
    use std::fmt::Write as _;
    if labels.is_empty() {
        return;
    }
    out.push('{');
    for (i, (k, v)) in labels.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(out, "{k}=\"{}\"", v.replace('\\', "\\\\").replace('"', "\\\""));
    }
    out.push('}');
}

/// Writes the `# HELP` / `# TYPE` header for a metric (once per name).
pub fn prom_header(out: &mut String, name: &str, kind: &str, help: &str) {
    use std::fmt::Write as _;
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} {kind}");
}

/// Writes one labelled sample line (`name{labels} value`).
pub fn prom_sample(out: &mut String, name: &str, labels: &[(&str, &str)], value: f64) {
    use std::fmt::Write as _;
    out.push_str(name);
    write_labels(out, labels);
    if value.is_nan() {
        let _ = writeln!(out, " NaN");
    } else if value == f64::INFINITY {
        let _ = writeln!(out, " +Inf");
    } else {
        let _ = writeln!(out, " {value}");
    }
}

/// Writes a full histogram family member — cumulative `_bucket` lines
/// over [`LATENCY_BUCKETS_S`] plus `+Inf`, `_sum`, and `_count` — from
/// raw samples (the windowed `Recorder` series).
pub fn prom_histogram(out: &mut String, name: &str, labels: &[(&str, &str)], samples: &[f64]) {
    use std::fmt::Write as _;
    for le in LATENCY_BUCKETS_S {
        let cumulative = samples.iter().filter(|&&x| x <= le).count();
        out.push_str(name);
        out.push_str("_bucket");
        let mut ls: Vec<(&str, &str)> = labels.to_vec();
        let le_s = format!("{le}");
        ls.push(("le", &le_s));
        write_labels(out, &ls);
        let _ = writeln!(out, " {cumulative}");
    }
    out.push_str(name);
    out.push_str("_bucket");
    let mut ls: Vec<(&str, &str)> = labels.to_vec();
    ls.push(("le", "+Inf"));
    write_labels(out, &ls);
    let _ = writeln!(out, " {}", samples.len());
    prom_sample(out, &format!("{name}_sum"), labels, samples.iter().sum());
    prom_sample(out, &format!("{name}_count"), labels, samples.len() as f64);
}

/// Validates Prometheus text-exposition format line by line: `# HELP` /
/// `# TYPE` comments, blank lines, and `name{labels} value` samples with
/// legal metric-name characters and parseable values. Used by the unit
/// tests and the `serving_trace_mock` acceptance check.
pub fn validate_prometheus(text: &str) -> crate::Result<()> {
    fn name_ok(s: &str) -> bool {
        !s.is_empty()
            && s.chars().next().is_some_and(|c| c.is_ascii_alphabetic() || c == '_' || c == ':')
            && s.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
    }
    for (i, line) in text.lines().enumerate() {
        let n = i + 1;
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# ") {
            let mut parts = rest.splitn(3, ' ');
            let what = parts.next().unwrap_or("");
            let name = parts.next().unwrap_or("");
            anyhow::ensure!(
                what == "HELP" || what == "TYPE",
                "line {n}: comment must be HELP or TYPE"
            );
            anyhow::ensure!(name_ok(name), "line {n}: bad metric name '{name}'");
            if what == "TYPE" {
                let kind = parts.next().unwrap_or("");
                anyhow::ensure!(
                    matches!(kind, "counter" | "gauge" | "histogram" | "summary" | "untyped"),
                    "line {n}: bad metric type '{kind}'"
                );
            }
            continue;
        }
        anyhow::ensure!(!line.starts_with('#'), "line {n}: malformed comment");
        // Sample line: name[{labels}] value
        let (head, value) = match line.find('}') {
            Some(close) => {
                let (h, rest) = line.split_at(close + 1);
                (h, rest.trim_start())
            }
            None => {
                let mut it = line.splitn(2, ' ');
                (it.next().unwrap_or(""), it.next().unwrap_or("").trim_start())
            }
        };
        let (name, labels) = match head.find('{') {
            Some(open) => {
                anyhow::ensure!(head.ends_with('}'), "line {n}: unterminated labels");
                (&head[..open], Some(&head[open + 1..head.len() - 1]))
            }
            None => (head, None),
        };
        anyhow::ensure!(name_ok(name), "line {n}: bad metric name '{name}'");
        if let Some(labels) = labels {
            for pair in labels.split(',').filter(|p| !p.is_empty()) {
                let (k, v) = pair
                    .split_once('=')
                    .ok_or_else(|| anyhow::anyhow!("line {n}: label without '='"))?;
                anyhow::ensure!(name_ok(k), "line {n}: bad label name '{k}'");
                anyhow::ensure!(
                    v.len() >= 2 && v.starts_with('"') && v.ends_with('"'),
                    "line {n}: unquoted label value"
                );
            }
        }
        let ok = matches!(value, "+Inf" | "-Inf" | "NaN") || value.parse::<f64>().is_ok();
        anyhow::ensure!(ok, "line {n}: unparseable value '{value}'");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(uid: u64, t: u64) -> TraceEvent {
        TraceEvent { uid, t_us: t, ..TraceEvent::EMPTY }
    }

    #[test]
    fn ring_keeps_most_recent_in_order() {
        let mut r = FlightRecorder::new(4);
        for i in 0..10u64 {
            r.push(ev(i, i));
        }
        assert_eq!(r.len(), 4);
        assert_eq!(r.total(), 10);
        let uids: Vec<u64> = r.to_vec().iter().map(|e| e.uid).collect();
        assert_eq!(uids, vec![6, 7, 8, 9]);
    }

    #[test]
    fn ring_below_capacity_keeps_everything() {
        let mut r = FlightRecorder::new(8);
        for i in 0..3u64 {
            r.push(ev(i, i));
        }
        let uids: Vec<u64> = r.to_vec().iter().map(|e| e.uid).collect();
        assert_eq!(uids, vec![0, 1, 2]);
    }

    #[test]
    fn zero_capacity_ring_is_inert() {
        let mut r = FlightRecorder::new(0);
        r.push(ev(1, 1));
        assert!(r.is_empty());
        assert_eq!(r.total(), 0);
        assert!(r.to_vec().is_empty());
    }

    #[test]
    fn tracer_stamps_worker_round_and_pairs_spans() {
        let t = Tracer::new(3, 64);
        t.set_round(7);
        let s = t.begin(Name::Round, 0);
        t.instant(Name::Admit, 42, 0);
        t.end(Name::Round, 0, s);
        let evs = t.events();
        assert_eq!(evs.len(), 3);
        assert!(evs.iter().all(|e| e.worker == 3 && e.round == 7));
        assert_eq!(evs[0].kind, Kind::SpanBegin);
        assert_eq!(evs[2].kind, Kind::SpanEnd);
        assert_eq!(evs[0].span, evs[2].span);
        assert_eq!(evs[1].uid, 42);
    }

    #[test]
    fn window_selects_recent_rounds_only() {
        let t = Tracer::new(0, 1024);
        for round in 1..=10u64 {
            t.set_round(round);
            t.instant(Name::Admit, round, 0);
        }
        let w = t.window(3);
        let rounds: Vec<u64> = w.iter().map(|e| e.round).collect();
        assert_eq!(rounds, vec![8, 9, 10]);
    }

    #[test]
    fn chrome_trace_round_trips_through_the_parser() {
        let t = Tracer::new(1, 64);
        t.set_round(2);
        let s = t.begin(Name::Verify, 0);
        t.end(Name::Verify, 0, s);
        t.instant(Name::Steal, 99, 0);
        let doc = chrome_trace(&t.events());
        let back = Json::parse(&doc.to_string()).unwrap();
        let evs = back.arr("traceEvents").unwrap();
        assert_eq!(evs.len(), 3);
        assert_eq!(evs[0].str("ph").unwrap(), "B");
        assert_eq!(evs[1].str("ph").unwrap(), "E");
        assert_eq!(evs[2].str("ph").unwrap(), "i");
        assert_eq!(evs[2].str("s").unwrap(), "t");
        assert_eq!(evs[2].req("args").unwrap().u64("uid").unwrap(), 99);
        assert_eq!(evs[0].f64("pid").unwrap(), 1.0);
    }

    #[test]
    fn exposition_helpers_emit_valid_text() {
        let mut out = String::new();
        prom_header(&mut out, "ygg_requests_total", "counter", "Requests accepted.");
        prom_sample(&mut out, "ygg_requests_total", &[("worker", "0")], 17.0);
        prom_header(&mut out, "ygg_ttft_seconds", "histogram", "Time to first token.");
        prom_histogram(
            &mut out,
            "ygg_ttft_seconds",
            &[("worker", "fleet")],
            &[0.002, 0.004, 0.3, 5.0],
        );
        validate_prometheus(&out).unwrap();
        assert!(out.contains("ygg_ttft_seconds_bucket{worker=\"fleet\",le=\"+Inf\"} 4"));
        assert!(out.contains("ygg_ttft_seconds_count{worker=\"fleet\"} 4"));
    }

    #[test]
    fn validator_rejects_malformed_lines() {
        assert!(validate_prometheus("1bad_name 3").is_err());
        assert!(validate_prometheus("x{le=unquoted} 3").is_err());
        assert!(validate_prometheus("x three").is_err());
        assert!(validate_prometheus("#! not a help").is_err());
        assert!(validate_prometheus("# TYPE x flavour").is_err());
        validate_prometheus("x{le=\"0.5\"} 3\n# HELP x h\n# TYPE x gauge\nx 1").unwrap();
    }

    #[test]
    fn format_window_is_one_line_per_event() {
        let t = Tracer::new(2, 16);
        t.instant(Name::Preempt, 5, 0);
        t.instant(Name::Resume, 5, 1);
        let s = format_window(&t.events());
        assert_eq!(s.lines().count(), 2);
        assert!(s.contains("preempt"));
        assert!(s.contains("uid=5"));
    }
}
