//! `yggdrasil` — the launcher.
//!
//! ```text
//! yggdrasil generate        --prompt-dataset c4s --max-new 64 [--engine yggdrasil]
//! yggdrasil serve           --addr 127.0.0.1:7777 [--no-stream]
//! yggdrasil profile         --reps 10            (writes artifacts/profile.*.json)
//! yggdrasil train-predictor --steps 8            (writes artifacts/predictor.*.json)
//! yggdrasil figures         --exp all|table1|fig4..fig15 [--quick]
//! ```
//!
//! Everything runs against the AOT artifacts (`make artifacts`); Python is
//! never invoked at runtime.

use yggdrasil::baselines::build_engine;
use yggdrasil::bench::{run_experiment, BenchOpts};
use yggdrasil::config::{AppConfig, EngineConfig};
use yggdrasil::corpus::PromptSet;
use yggdrasil::engine::{profiling, Engine, SpecDecoder, StepEngine};
use yggdrasil::predictor::{DepthPredictor, DepthSample};
use yggdrasil::runtime::Runtime;
use yggdrasil::server::{RoutingPolicy, ServeOpts, Server, SloClass};
use yggdrasil::util::cli::Args;
use yggdrasil::util::log::{self, Level};

const OPTS: &[&str] = &[
    "config", "artifacts", "engine", "drafter", "target", "prompt-dataset", "prompt-index",
    "max-new", "temperature", "seed", "addr", "reps", "steps", "exp", "out-dir", "max-depth",
    "max-width", "max-verify", "max-sessions", "block-size", "cache-blocks", "cpu-threads",
    "prefill-chunk", "slo-class", "workers", "routing", "trace-out", "trace-ring", "log-level",
];
const FLAGS: &[&str] = &[
    "quick",
    "no-stream",
    "eager",
    "round-robin",
    "paged",
    "equal-partition",
    "no-batch-draft",
    "prefix-cache",
    "no-prefix-cache",
    "global-alloc",
    "no-global-alloc",
    "help",
];

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        log::error(&format!("{e:#}"));
        std::process::exit(1);
    }
}

fn run(argv: &[String]) -> yggdrasil::Result<()> {
    let args = Args::parse(argv, OPTS, FLAGS)?;
    if let Some(l) = args.get("log-level") {
        log::set_level(Level::parse(l)?);
    }
    if args.flag("help") || args.subcommand.is_none() {
        print_help();
        return Ok(());
    }
    let mut app = match args.get("config") {
        Some(p) => AppConfig::load(std::path::Path::new(p))?,
        None => AppConfig::default(),
    };
    if let Some(dir) = args.get("artifacts") {
        app.runtime.artifacts_dir = dir.into();
    }
    apply_engine_overrides(&mut app.engine, &args)?;

    match args.subcommand.as_deref().unwrap() {
        "generate" => cmd_generate(&app, &args),
        "serve" => cmd_serve(&app, &args),
        "profile" => cmd_profile(&app, &args),
        "train-predictor" => cmd_train_predictor(&app, &args),
        "figures" => cmd_figures(&app, &args),
        other => anyhow::bail!("unknown subcommand '{other}' (try --help)"),
    }
}

fn apply_engine_overrides(cfg: &mut EngineConfig, args: &Args) -> yggdrasil::Result<()> {
    if let Some(d) = args.get("drafter") {
        cfg.drafter = d.into();
    }
    if let Some(t) = args.get("target") {
        cfg.target = t.into();
    }
    cfg.max_new_tokens = args.usize_or("max-new", cfg.max_new_tokens)?;
    cfg.max_depth = args.usize_or("max-depth", cfg.max_depth)?;
    cfg.max_width = args.usize_or("max-width", cfg.max_width)?;
    cfg.max_verify = args.usize_or("max-verify", cfg.max_verify)?;
    cfg.sampling.temperature = args.f64_or("temperature", cfg.sampling.temperature as f64)? as f32;
    cfg.sampling.seed = args.u64_or("seed", cfg.sampling.seed)?;
    if args.flag("eager") {
        cfg.compiled = false;
    }
    Ok(())
}

/// Fits the tree envelope to the shared-cache layout (DESIGN.md §9–§10).
///
/// Equal partition: each session owns only `(capacity - 1) / max_sessions`
/// KV slots; the default single-session envelope would eat the whole
/// quota and admission would reject every prompt, so shrink it. Paged:
/// there is no fixed quota (the per-iteration budget clamps to pool
/// headroom at runtime) — just validate the block layout eagerly so a bad
/// `--block-size`/`--cache-blocks` surfaces as a typed startup error, and
/// shrink envelopes that oversize the *whole* pool.
fn fit_batched_envelope(cfg: &mut EngineConfig, rt: &Runtime) -> yggdrasil::Result<()> {
    if !cfg.batch.enabled {
        return Ok(());
    }
    let cap = rt
        .spec(&cfg.drafter)?
        .cache_capacity
        .min(rt.spec(&cfg.target)?.cache_capacity);
    let quota = if cfg.batch.paged {
        // Startup validation of the paged layout (typed CacheConfigError).
        yggdrasil::kvcache::BlockPool::new(cap, cfg.batch.block_size, cfg.batch.cache_blocks)?;
        cap.saturating_sub(1)
    } else {
        // Cap the session count itself first: each region needs ≥ 2 slots
        // or the shared cache cannot be partitioned at all.
        let max_fit = (cap.saturating_sub(1) / 2).max(1);
        if cfg.batch.max_sessions > max_fit {
            log::warn(&format!(
                "batched serving: {} sessions cannot share a {cap}-slot cache; \
                 capping at {max_fit}",
                cfg.batch.max_sessions
            ));
            cfg.batch.max_sessions = max_fit;
        }
        cap.saturating_sub(1) / cfg.batch.max_sessions.max(1)
    };
    let budget = |c: &EngineConfig| c.max_depth * c.max_width + c.max_verify + 8;
    // Keep ≥ 24 slots of the quota for the committed prefix + generation.
    if budget(cfg) > quota.saturating_sub(24) {
        let before = (cfg.max_depth, cfg.max_width, cfg.max_verify);
        cfg.max_depth = cfg.max_depth.min(4);
        cfg.max_width = cfg.max_width.min(4);
        cfg.max_verify = cfg.max_verify.min(16);
        // Tiny quotas (many sessions on a small cache): keep shrinking so
        // admission headroom stays positive instead of rejecting 100%.
        while budget(cfg) > quota.saturating_sub(16)
            && (cfg.max_verify > 4 || cfg.max_width > 1 || cfg.max_depth > 2)
        {
            if cfg.max_verify > 4 {
                cfg.max_verify = (cfg.max_verify / 2).max(4);
            } else if cfg.max_width > 1 {
                cfg.max_width /= 2;
            } else {
                cfg.max_depth -= 1;
            }
        }
        log::warn(&format!(
            "batched serving: tree envelope D{} W{} Wv{} oversizes the per-session \
             KV quota ({quota} slots); fitted to D{} W{} Wv{}",
            before.0, before.1, before.2, cfg.max_depth, cfg.max_width, cfg.max_verify
        ));
    }
    Ok(())
}

/// Loads the runtime + latency model + optional trained predictor and
/// builds the configured engine (step-driven, so it can serve).
fn build(app: &AppConfig, args: &Args) -> yggdrasil::Result<(Runtime, Box<dyn StepEngine + Send>)> {
    let (rt, mut engines) = build_fleet(app, args, 1)?;
    Ok((rt, engines.pop().expect("build_fleet(1) returns one engine")))
}

/// Like [`build`], but constructs `workers` independent engines from one
/// loaded runtime (DESIGN.md §16): the heavy pieces — weights, compiled
/// executables, latency profile, trained predictor — load once and are
/// shared/cloned, while each engine gets its own cache pool and prefix
/// trie (that isolation is what the router's affinity placement routes
/// around).
fn build_fleet(
    app: &AppConfig,
    args: &Args,
    workers: usize,
) -> yggdrasil::Result<(Runtime, Vec<Box<dyn StepEngine + Send>>)> {
    anyhow::ensure!(workers >= 1, "--workers must be at least 1");
    let dir = &app.runtime.artifacts_dir;
    let mut cfg = app.engine.clone();
    let rt = Runtime::load(dir, &[cfg.drafter.as_str(), cfg.target.as_str()])?;
    fit_batched_envelope(&mut cfg, &rt)?;
    let engine_name = args.str_or("engine", "yggdrasil");
    let lat = profiling::load_or_profile(
        &rt,
        &cfg.drafter,
        &cfg.target,
        app.runtime.profile_file.as_deref(),
        5,
    )?;
    // Per-fleet one-time loads/validation, outside the per-worker loop.
    let predictor = if engine_name == "yggdrasil" {
        let p = app
            .runtime
            .predictor_file
            .as_ref()
            .map(|p| profiling::keyed_path(p, &cfg.drafter, &cfg.target))
            .filter(|p| p.exists())
            .and_then(|p| DepthPredictor::load(&p).ok());
        if p.is_some() {
            log::info("loaded trained depth predictor");
        }
        p
    } else {
        None
    };
    let preset = match engine_name.as_str() {
        "yggdrasil" | "vanilla" => None,
        name => {
            // Validate via the factory, then rebuild the Send version
            // with the session-level overrides applied.
            let e = build_engine(&rt, name, (&cfg.drafter, &cfg.target), &lat)?;
            drop(e);
            let mut p = match name {
                "seqspec" => EngineConfig::preset_seqspec(5),
                "specinfer" => EngineConfig::preset_specinfer(4, 4, 64),
                "sequoia" => EngineConfig::preset_sequoia(32),
                "vllmspec" => EngineConfig::preset_vllmspec(5),
                other => anyhow::bail!("unknown engine '{other}'"),
            };
            p.drafter = cfg.drafter.clone();
            p.target = cfg.target.clone();
            p.sampling = cfg.sampling.clone();
            Some(p)
        }
    };
    let engines = (0..workers)
        .map(|_| -> Box<dyn StepEngine + Send> {
            if engine_name == "yggdrasil" {
                Box::new(SpecDecoder::new(&rt, cfg.clone(), lat.clone(), predictor.clone()))
            } else if engine_name == "vanilla" {
                Box::new(yggdrasil::baselines::VanillaEngine::new(&rt, &cfg.target, true))
            } else {
                // Baseline presets keep owned caches (their envelopes
                // outsize the shared-cache per-session quota); the
                // server's batched rounds then fall back to serial
                // stepping gracefully.
                let p = preset.clone().expect("preset resolved above");
                Box::new(SpecDecoder::new(&rt, p, lat.clone(), None))
            }
        })
        .collect();
    Ok((rt, engines))
}

fn cmd_generate(app: &AppConfig, args: &Args) -> yggdrasil::Result<()> {
    let (_rt, mut engine) = build(app, args)?;
    let ds = args.str_or("prompt-dataset", "c4s");
    let idx = args.usize_or("prompt-index", 0)?;
    let prompts = PromptSet::load(&app.runtime.artifacts_dir, &ds)?;
    let prompt = prompts
        .prompts
        .get(idx)
        .ok_or_else(|| anyhow::anyhow!("prompt index {idx} out of range"))?;
    let max_new = app.engine.max_new_tokens;
    log::info(&format!("engine: {}", engine.name()));
    log::info(&format!("prompt ({ds}[{idx}]): {prompt:?}"));
    let g = engine.generate_with(prompt, max_new, &mut |toks| {
        for t in toks {
            print!("{t} ");
        }
        use std::io::Write as _;
        let _ = std::io::stdout().flush();
    })?;
    println!();
    log::info(&format!(
        "{} tokens in {} iterations — AAL {:.2}, {:.2} ms/token (prefill {:.1} ms)",
        g.tokens.len(),
        g.iterations,
        g.aal(),
        g.tpot() * 1e3,
        g.prefill_seconds * 1e3,
    ));
    Ok(())
}

fn cmd_serve(app: &AppConfig, args: &Args) -> yggdrasil::Result<()> {
    let mut app = app.clone();
    let batched = app.server.batched && !args.flag("round-robin");
    let max_sessions = args.usize_or("max-sessions", app.server.max_sessions)?;
    if batched {
        // Cross-session batching: the engine shares one cache pair across
        // the server's session slots — paged block leasing by default
        // (DESIGN.md §10), equal fixed regions with `--equal-partition`
        // (DESIGN.md §9).
        app.engine.batch.enabled = true;
        app.engine.batch.max_sessions = max_sessions;
        if args.flag("equal-partition") {
            app.engine.batch.paged = false;
        }
        if args.flag("paged") {
            app.engine.batch.paged = true;
        }
        if args.flag("no-batch-draft") {
            // Verify-only batching (DESIGN.md §9): each session's draft
            // calls issue serially; only the verify stage packs.
            app.engine.batch.batch_draft = false;
        }
        if args.flag("no-prefix-cache") {
            // Every request prefills its whole prompt (DESIGN.md §12 off).
            app.engine.batch.prefix_cache = false;
        }
        if args.flag("prefix-cache") {
            app.engine.batch.prefix_cache = true;
        }
        if args.flag("no-global-alloc") {
            // Per-session static verify budgets; no round-level
            // redistribution across packed sessions (DESIGN.md §15 off).
            app.engine.batch.global_alloc = false;
        }
        if args.flag("global-alloc") {
            app.engine.batch.global_alloc = true;
        }
        app.engine.batch.block_size =
            args.usize_or("block-size", app.engine.batch.block_size)?;
        // Per-session CPU stages of a round: 1 = serial (default), 0 =
        // auto, N = fan out across N scoped threads (DESIGN.md §13).
        app.engine.batch.cpu_threads =
            args.usize_or("cpu-threads", app.engine.batch.cpu_threads)?;
        // Chunked prefill (DESIGN.md §14): cap cold-prompt prefill work
        // per batched round; 0 prefills whole prompts in one shot.
        app.engine.batch.prefill_chunk =
            args.usize_or("prefill-chunk", app.engine.batch.prefill_chunk)?;
        if let Some(b) = args.get("cache-blocks") {
            let blocks: usize = b
                .parse()
                .map_err(|_| anyhow::anyhow!("--cache-blocks needs an integer, got '{b}'"))?;
            app.engine.batch.cache_blocks = Some(blocks);
        }
    }
    let app = &app;
    // Data-parallel sharding (DESIGN.md §16): N engine workers behind one
    // listener, each with its own cache pool and prefix trie.
    let workers = args.usize_or("workers", app.server.workers)?.max(1);
    let routing = match args.get("routing") {
        Some(r) => RoutingPolicy::from_str(r)?,
        None => app.server.routing,
    };
    let (_rt, engines) = build_fleet(app, args, workers)?;
    let addr = args.str_or("addr", &app.server.addr);
    let stream = app.server.stream && !args.flag("no-stream");
    let opts = ServeOpts {
        max_queue: app.server.max_queue,
        max_sessions,
        stream,
        batched,
        routing,
        default_class: match args.get("slo-class") {
            Some(s) => SloClass::from_str(s)?,
            None => ServeOpts::default().default_class,
        },
        // Observability (DESIGN.md §17): per-worker flight-recorder
        // capacity (0 disables tracing) and an optional Chrome-trace
        // dump written on shutdown.
        trace_ring: args.usize_or("trace-ring", ServeOpts::default().trace_ring)?,
        trace_out: args.get("trace-out").map(std::path::PathBuf::from),
        ..ServeOpts::default()
    };
    let max_sessions = opts.max_sessions;
    let mut layout = match (batched, app.engine.batch.paged, app.engine.batch.batch_draft) {
        (false, _, _) => "round-robin",
        (true, true, true) => "batched+paged",
        (true, true, false) => "batched+paged (verify-only)",
        (true, false, true) => "batched+equal-partition",
        (true, false, false) => "batched+equal-partition (verify-only)",
    }
    .to_string();
    if batched && app.engine.batch.paged {
        layout.push_str(if app.engine.batch.prefix_cache {
            "+prefix-cache"
        } else {
            " (prefix cache off)"
        });
    }
    let srv = Server::spawn_fleet(&addr, engines, opts)?;
    log::info(&format!(
        "serving on {} (stream={stream}, max_sessions={max_sessions}, \
         workers={workers}, routing={}, mode={layout}) — Ctrl-C to stop",
        srv.addr,
        routing.as_str(),
    ));
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_profile(app: &AppConfig, args: &Args) -> yggdrasil::Result<()> {
    let cfg = &app.engine;
    let rt =
        Runtime::load(&app.runtime.artifacts_dir, &[cfg.drafter.as_str(), cfg.target.as_str()])?;
    let reps = args.usize_or("reps", 10)?;
    let model = profiling::profile_latency_model(&rt, &cfg.drafter, &cfg.target, reps)?;
    let base = app
        .runtime
        .profile_file
        .clone()
        .unwrap_or_else(|| app.runtime.artifacts_dir.join("profile.json"));
    let path = profiling::keyed_path(&base, &cfg.drafter, &cfg.target);
    model.save(&path)?;
    println!("profile ({reps} reps/width) -> {}", path.display());
    for &w in yggdrasil::config::GRAPH_WIDTHS.iter() {
        println!(
            "  w={w:<3} drafter {:8.3} ms   verifier {:8.3} ms",
            model.t_draft(w) * 1e3,
            model.t_verify(w) * 1e3
        );
    }
    Ok(())
}

fn cmd_train_predictor(app: &AppConfig, args: &Args) -> yggdrasil::Result<()> {
    let cfg = app.engine.clone();
    let rt =
        Runtime::load(&app.runtime.artifacts_dir, &[cfg.drafter.as_str(), cfg.target.as_str()])?;
    let lat = profiling::load_or_profile(
        &rt,
        &cfg.drafter,
        &cfg.target,
        app.runtime.profile_file.as_deref(),
        5,
    )?;
    // Collect (hidden, accepted) pairs by running the engine (predictor
    // off) over the calibration datasets.
    let mut collect_cfg = cfg.clone();
    collect_cfg.use_depth_predictor = false;
    let mut dec = SpecDecoder::new(&rt, collect_cfg, lat, None);
    let epochs = args.usize_or("steps", 8)?;
    let mut samples: Vec<DepthSample> = Vec::new();
    for ds in yggdrasil::corpus::DATASETS {
        let prompts = PromptSet::load(&app.runtime.artifacts_dir, ds)?;
        for p in prompts.prompts.iter().take(if args.flag("quick") { 2 } else { 6 }) {
            let _ = dec.generate(p, cfg.max_new_tokens)?;
            samples.extend(
                dec.take_depth_samples()
                    .into_iter()
                    .map(|(hidden, accepted)| DepthSample { hidden, accepted }),
            );
        }
        log::info(&format!("collected {} samples after {ds}", samples.len()));
    }
    anyhow::ensure!(samples.len() >= 32, "not enough samples ({})", samples.len());
    let dim = samples[0].hidden.len();
    let mut pred = DepthPredictor::new(dim, 32, cfg.max_depth, 7);
    let loss = pred.train(&samples, epochs, 1e-3, 11);
    let base = app
        .runtime
        .predictor_file
        .clone()
        .unwrap_or_else(|| app.runtime.artifacts_dir.join("predictor.json"));
    let path = profiling::keyed_path(&base, &cfg.drafter, &cfg.target);
    pred.save(&path)?;
    println!(
        "trained depth predictor on {} samples ({epochs} epochs, final loss {loss:.4}) -> {}",
        samples.len(),
        path.display()
    );
    Ok(())
}

fn cmd_figures(app: &AppConfig, args: &Args) -> yggdrasil::Result<()> {
    let exp = args.str_or("exp", "all");
    let opts = BenchOpts {
        artifacts_dir: app.runtime.artifacts_dir.clone(),
        out_dir: args.str_or("out-dir", "results").into(),
        quick: args.flag("quick"),
        seed: args.u64_or("seed", 0)?,
    };
    run_experiment(&exp, opts)
}

fn print_help() {
    println!(
        "yggdrasil — latency-optimal tree-based speculative decoding

USAGE: yggdrasil <subcommand> [options]

SUBCOMMANDS
  generate         decode one prompt and print tokens (streaming)
  serve            TCP JSON-lines server (see rust/src/server)
  profile          measure T_drafter/T_verifier latency curves
  train-predictor  train the draft-depth predictor from profiling runs
  figures          regenerate the paper's tables/figures (--exp all|figN)

COMMON OPTIONS
  --artifacts DIR     artifact bundle (default: artifacts)
  --config FILE       JSON config (AppConfig)
  --engine NAME       yggdrasil|vanilla|seqspec|specinfer|sequoia|vllmspec
  --drafter / --target model names (default dft-xs / tgt-sm)
  --max-new N --temperature T --seed S
  --max-sessions N    concurrent sessions to interleave (serve)
  --round-robin       serve with serial time-slicing instead of
                      cross-session batching
  --no-batch-draft    batch only the verify stage across sessions; draft
                      calls issue serially per session (serve; default
                      packs head + every tree-draft level too)
  --paged             lease the shared KV cache block-by-block on demand
                      with preempt/resume under pressure (serve; default)
  --equal-partition   fall back to equal fixed per-session cache regions
  --block-size N      slots per paged cache block (default 16)
  --cache-blocks N    cap the paged pool below device capacity
  --no-prefix-cache   prefill every prompt from token zero instead of
                      reusing cached cross-request prefix blocks
                      (serve; the paged default caches shared prefixes)
  --prefix-cache      re-enable the prefix cache over a config file
  --prefill-chunk N   cap cold-prompt prefill tokens per batched round
                      so long prompts cannot stall warm streams
                      (serve; 0 = whole prompt in one round)
  --slo-class CLASS   default SLO class for untagged requests:
                      latency (default) or throughput (serve)
  --workers N         data-parallel engine workers behind one listener,
                      each with its own cache pool and prefix trie
                      (serve; default 1)
  --routing POLICY    request placement across workers: affinity
                      (default; prefix-cache-aware), round-robin, or
                      least-loaded (serve)
  --no-global-alloc   give every packed session its own static verify
                      budget instead of redistributing a round-wide
                      budget by online acceptance rate (serve)
  --global-alloc      re-enable the round allocator over a config file
  --trace-ring N      per-worker flight-recorder capacity in events
                      (serve; default 8192, 0 disables tracing)
  --trace-out FILE    write the fleet's trace as Chrome trace-event JSON
                      on shutdown — load it in Perfetto / chrome://tracing
                      (serve)
  --log-level LEVEL   stderr verbosity: error|warn|info|debug
                      (default info)
  --exp EXP --quick --out-dir DIR   (figures)
"
    );
}
