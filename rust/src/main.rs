//! `yggdrasil` — the launcher.
//!
//! ```text
//! yggdrasil generate        --prompt-dataset c4s --max-new 64 [--engine yggdrasil]
//! yggdrasil serve           --addr 127.0.0.1:7777 [--no-stream]
//! yggdrasil profile         --reps 10            (writes artifacts/profile.*.json)
//! yggdrasil train-predictor --steps 8            (writes artifacts/predictor.*.json)
//! yggdrasil figures         --exp all|table1|fig4..fig15 [--quick]
//! ```
//!
//! Everything runs against the AOT artifacts (`make artifacts`); Python is
//! never invoked at runtime.

use yggdrasil::baselines::build_engine;
use yggdrasil::bench::{run_experiment, BenchOpts};
use yggdrasil::config::{AppConfig, EngineConfig};
use yggdrasil::corpus::PromptSet;
use yggdrasil::engine::{profiling, Engine, SpecDecoder, StepEngine};
use yggdrasil::predictor::{DepthPredictor, DepthSample};
use yggdrasil::runtime::Runtime;
use yggdrasil::server::{ServeOpts, Server};
use yggdrasil::util::cli::Args;

const OPTS: &[&str] = &[
    "config", "artifacts", "engine", "drafter", "target", "prompt-dataset", "prompt-index",
    "max-new", "temperature", "seed", "addr", "reps", "steps", "exp", "out-dir", "max-depth",
    "max-width", "max-verify", "max-sessions",
];
const FLAGS: &[&str] = &["quick", "no-stream", "eager", "help"];

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: &[String]) -> yggdrasil::Result<()> {
    let args = Args::parse(argv, OPTS, FLAGS)?;
    if args.flag("help") || args.subcommand.is_none() {
        print_help();
        return Ok(());
    }
    let mut app = match args.get("config") {
        Some(p) => AppConfig::load(std::path::Path::new(p))?,
        None => AppConfig::default(),
    };
    if let Some(dir) = args.get("artifacts") {
        app.runtime.artifacts_dir = dir.into();
    }
    apply_engine_overrides(&mut app.engine, &args)?;

    match args.subcommand.as_deref().unwrap() {
        "generate" => cmd_generate(&app, &args),
        "serve" => cmd_serve(&app, &args),
        "profile" => cmd_profile(&app, &args),
        "train-predictor" => cmd_train_predictor(&app, &args),
        "figures" => cmd_figures(&app, &args),
        other => anyhow::bail!("unknown subcommand '{other}' (try --help)"),
    }
}

fn apply_engine_overrides(cfg: &mut EngineConfig, args: &Args) -> yggdrasil::Result<()> {
    if let Some(d) = args.get("drafter") {
        cfg.drafter = d.into();
    }
    if let Some(t) = args.get("target") {
        cfg.target = t.into();
    }
    cfg.max_new_tokens = args.usize_or("max-new", cfg.max_new_tokens)?;
    cfg.max_depth = args.usize_or("max-depth", cfg.max_depth)?;
    cfg.max_width = args.usize_or("max-width", cfg.max_width)?;
    cfg.max_verify = args.usize_or("max-verify", cfg.max_verify)?;
    cfg.sampling.temperature = args.f64_or("temperature", cfg.sampling.temperature as f64)? as f32;
    cfg.sampling.seed = args.u64_or("seed", cfg.sampling.seed)?;
    if args.flag("eager") {
        cfg.compiled = false;
    }
    Ok(())
}

/// Loads the runtime + latency model + optional trained predictor and
/// builds the configured engine (step-driven, so it can serve).
fn build(app: &AppConfig, args: &Args) -> yggdrasil::Result<(Runtime, Box<dyn StepEngine + Send>)> {
    let dir = &app.runtime.artifacts_dir;
    let cfg = app.engine.clone();
    let rt = Runtime::load(dir, &[cfg.drafter.as_str(), cfg.target.as_str()])?;
    let engine_name = args.str_or("engine", "yggdrasil");
    let lat = profiling::load_or_profile(
        &rt,
        &cfg.drafter,
        &cfg.target,
        app.runtime.profile_file.as_deref(),
        5,
    )?;
    let boxed: Box<dyn StepEngine + Send> = if engine_name == "yggdrasil" {
        let predictor = app
            .runtime
            .predictor_file
            .as_ref()
            .map(|p| profiling::keyed_path(p, &cfg.drafter, &cfg.target))
            .filter(|p| p.exists())
            .and_then(|p| DepthPredictor::load(&p).ok());
        if predictor.is_some() {
            eprintln!("loaded trained depth predictor");
        }
        Box::new(SpecDecoder::new(&rt, cfg.clone(), lat, predictor))
    } else if engine_name == "vanilla" {
        Box::new(yggdrasil::baselines::VanillaEngine::new(&rt, &cfg.target, true))
    } else {
        // Validate via the factory, then rebuild the Send version with the
        // session-level overrides applied.
        let e = build_engine(&rt, &engine_name, (&cfg.drafter, &cfg.target), &lat)?;
        drop(e);
        let mut p = match engine_name.as_str() {
            "seqspec" => EngineConfig::preset_seqspec(5),
            "specinfer" => EngineConfig::preset_specinfer(4, 4, 64),
            "sequoia" => EngineConfig::preset_sequoia(32),
            "vllmspec" => EngineConfig::preset_vllmspec(5),
            other => anyhow::bail!("unknown engine '{other}'"),
        };
        p.drafter = cfg.drafter.clone();
        p.target = cfg.target.clone();
        p.sampling = cfg.sampling.clone();
        Box::new(SpecDecoder::new(&rt, p, lat, None))
    };
    Ok((rt, boxed))
}

fn cmd_generate(app: &AppConfig, args: &Args) -> yggdrasil::Result<()> {
    let (_rt, mut engine) = build(app, args)?;
    let ds = args.str_or("prompt-dataset", "c4s");
    let idx = args.usize_or("prompt-index", 0)?;
    let prompts = PromptSet::load(&app.runtime.artifacts_dir, &ds)?;
    let prompt = prompts
        .prompts
        .get(idx)
        .ok_or_else(|| anyhow::anyhow!("prompt index {idx} out of range"))?;
    let max_new = app.engine.max_new_tokens;
    eprintln!("engine: {}", engine.name());
    eprintln!("prompt ({ds}[{idx}]): {prompt:?}");
    let g = engine.generate_with(prompt, max_new, &mut |toks| {
        for t in toks {
            print!("{t} ");
        }
        use std::io::Write as _;
        let _ = std::io::stdout().flush();
    })?;
    println!();
    eprintln!(
        "{} tokens in {} iterations — AAL {:.2}, {:.2} ms/token (prefill {:.1} ms)",
        g.tokens.len(),
        g.iterations,
        g.aal(),
        g.tpot() * 1e3,
        g.prefill_seconds * 1e3,
    );
    Ok(())
}

fn cmd_serve(app: &AppConfig, args: &Args) -> yggdrasil::Result<()> {
    let (_rt, engine) = build(app, args)?;
    let addr = args.str_or("addr", &app.server.addr);
    let stream = app.server.stream && !args.flag("no-stream");
    let opts = ServeOpts {
        max_queue: app.server.max_queue,
        max_sessions: args.usize_or("max-sessions", app.server.max_sessions)?,
        stream,
    };
    let max_sessions = opts.max_sessions;
    let srv = Server::spawn(&addr, engine, opts)?;
    eprintln!(
        "serving on {} (stream={stream}, max_sessions={max_sessions}) — Ctrl-C to stop",
        srv.addr
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_profile(app: &AppConfig, args: &Args) -> yggdrasil::Result<()> {
    let cfg = &app.engine;
    let rt =
        Runtime::load(&app.runtime.artifacts_dir, &[cfg.drafter.as_str(), cfg.target.as_str()])?;
    let reps = args.usize_or("reps", 10)?;
    let model = profiling::profile_latency_model(&rt, &cfg.drafter, &cfg.target, reps)?;
    let base = app
        .runtime
        .profile_file
        .clone()
        .unwrap_or_else(|| app.runtime.artifacts_dir.join("profile.json"));
    let path = profiling::keyed_path(&base, &cfg.drafter, &cfg.target);
    model.save(&path)?;
    println!("profile ({reps} reps/width) -> {}", path.display());
    for &w in yggdrasil::config::GRAPH_WIDTHS.iter() {
        println!(
            "  w={w:<3} drafter {:8.3} ms   verifier {:8.3} ms",
            model.t_draft(w) * 1e3,
            model.t_verify(w) * 1e3
        );
    }
    Ok(())
}

fn cmd_train_predictor(app: &AppConfig, args: &Args) -> yggdrasil::Result<()> {
    let cfg = app.engine.clone();
    let rt =
        Runtime::load(&app.runtime.artifacts_dir, &[cfg.drafter.as_str(), cfg.target.as_str()])?;
    let lat = profiling::load_or_profile(
        &rt,
        &cfg.drafter,
        &cfg.target,
        app.runtime.profile_file.as_deref(),
        5,
    )?;
    // Collect (hidden, accepted) pairs by running the engine (predictor
    // off) over the calibration datasets.
    let mut collect_cfg = cfg.clone();
    collect_cfg.use_depth_predictor = false;
    let mut dec = SpecDecoder::new(&rt, collect_cfg, lat, None);
    let epochs = args.usize_or("steps", 8)?;
    let mut samples: Vec<DepthSample> = Vec::new();
    for ds in yggdrasil::corpus::DATASETS {
        let prompts = PromptSet::load(&app.runtime.artifacts_dir, ds)?;
        for p in prompts.prompts.iter().take(if args.flag("quick") { 2 } else { 6 }) {
            let _ = dec.generate(p, cfg.max_new_tokens)?;
            samples.extend(
                dec.take_depth_samples()
                    .into_iter()
                    .map(|(hidden, accepted)| DepthSample { hidden, accepted }),
            );
        }
        eprintln!("collected {} samples after {ds}", samples.len());
    }
    anyhow::ensure!(samples.len() >= 32, "not enough samples ({})", samples.len());
    let dim = samples[0].hidden.len();
    let mut pred = DepthPredictor::new(dim, 32, cfg.max_depth, 7);
    let loss = pred.train(&samples, epochs, 1e-3, 11);
    let base = app
        .runtime
        .predictor_file
        .clone()
        .unwrap_or_else(|| app.runtime.artifacts_dir.join("predictor.json"));
    let path = profiling::keyed_path(&base, &cfg.drafter, &cfg.target);
    pred.save(&path)?;
    println!(
        "trained depth predictor on {} samples ({epochs} epochs, final loss {loss:.4}) -> {}",
        samples.len(),
        path.display()
    );
    Ok(())
}

fn cmd_figures(app: &AppConfig, args: &Args) -> yggdrasil::Result<()> {
    let exp = args.str_or("exp", "all");
    let opts = BenchOpts {
        artifacts_dir: app.runtime.artifacts_dir.clone(),
        out_dir: args.str_or("out-dir", "results").into(),
        quick: args.flag("quick"),
        seed: args.u64_or("seed", 0)?,
    };
    run_experiment(&exp, opts)
}

fn print_help() {
    println!(
        "yggdrasil — latency-optimal tree-based speculative decoding

USAGE: yggdrasil <subcommand> [options]

SUBCOMMANDS
  generate         decode one prompt and print tokens (streaming)
  serve            TCP JSON-lines server (see rust/src/server)
  profile          measure T_drafter/T_verifier latency curves
  train-predictor  train the draft-depth predictor from profiling runs
  figures          regenerate the paper's tables/figures (--exp all|figN)

COMMON OPTIONS
  --artifacts DIR     artifact bundle (default: artifacts)
  --config FILE       JSON config (AppConfig)
  --engine NAME       yggdrasil|vanilla|seqspec|specinfer|sequoia|vllmspec
  --drafter / --target model names (default dft-xs / tgt-sm)
  --max-new N --temperature T --seed S
  --max-sessions N    concurrent sessions to interleave (serve)
  --exp EXP --quick --out-dir DIR   (figures)
"
    );
}
