//! The draft-depth predictor — §4.2 "Draft Depth Prediction" (O5).
//!
//! A lightweight multi-head MLP consumes the verifier's last-token hidden
//! state and predicts how deep the next draft is worth growing: a two-layer
//! encoder feeds `max_depth` binary heads, head *d* estimating
//! `P(accepted length ≥ d+1)`. The expected acceptance length is the sum of
//! the head probabilities; the depth decision is its (clamped) ceiling.
//!
//! Everything is implemented from scratch in Rust — forward, backprop,
//! Adam — because the predictor must train *online from profiling runs of
//! this system* (`yggdrasil train-predictor`) and run inference inside the
//! decode loop with microsecond-level cost; its weights persist as JSON in
//! the artifacts directory.


use crate::sampling::XorShiftRng;
use crate::util::json::Json;

/// Row-major matrix with bias.
#[derive(Debug, Clone)]
struct Linear {
    w: Vec<f32>, // [out, in]
    b: Vec<f32>, // [out]
    rows: usize,
    cols: usize,
}

impl Linear {
    fn new(rows: usize, cols: usize, rng: &mut XorShiftRng) -> Self {
        let scale = (2.0 / cols as f32).sqrt();
        let w = (0..rows * cols)
            .map(|_| (rng.next_f32() * 2.0 - 1.0) * scale)
            .collect();
        Self { w, b: vec![0.0; rows], rows, cols }
    }

    fn forward(&self, x: &[f32], out: &mut Vec<f32>) {
        out.resize(self.rows, 0.0);
        for r in 0..self.rows {
            let row = &self.w[r * self.cols..(r + 1) * self.cols];
            let mut acc = self.b[r];
            for (a, b) in row.iter().zip(x) {
                acc += a * b;
            }
            out[r] = acc;
        }
    }

    /// Accumulates gradients; returns dL/dx into `dx`.
    fn backward(&self, x: &[f32], dy: &[f32], gw: &mut [f32], gb: &mut [f32], dx: &mut [f32]) {
        dx.iter_mut().for_each(|v| *v = 0.0);
        for r in 0..self.rows {
            let d = dy[r];
            gb[r] += d;
            let row = &self.w[r * self.cols..(r + 1) * self.cols];
            let grow = &mut gw[r * self.cols..(r + 1) * self.cols];
            for c in 0..self.cols {
                grow[c] += d * x[c];
                dx[c] += d * row[c];
            }
        }
    }

    fn param_len(&self) -> usize {
        self.w.len() + self.b.len()
    }
}

fn relu_inplace(x: &mut [f32]) {
    x.iter_mut().for_each(|v| *v = v.max(0.0));
}

fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// One (hidden-state, accepted-length) training example collected by the
/// profiling run.
#[derive(Debug, Clone)]
pub struct DepthSample {
    /// Verifier final-norm hidden state of the bonus context.
    pub hidden: Vec<f32>,
    /// Number of draft tokens accepted in the following iteration
    /// (excludes the bonus token), clamped to `max_depth`.
    pub accepted: usize,
}

/// The multi-head depth predictor.
#[derive(Debug, Clone)]
pub struct DepthPredictor {
    enc1: Linear,
    enc2: Linear,
    heads: Linear, // [max_depth, hidden]
    /// Expected hidden-state dimension.
    pub input_dim: usize,
    /// Encoder width.
    pub hidden_dim: usize,
    /// Number of depth heads (predicts `1..=max_depth`).
    pub max_depth: usize,
    /// Training metadata for EXPERIMENTS.md provenance.
    pub train_loss: f32,
    /// Samples seen by the last training run.
    pub train_samples: usize,
}

impl DepthPredictor {
    /// A randomly-initialised predictor.
    pub fn new(input_dim: usize, hidden_dim: usize, max_depth: usize, seed: u64) -> Self {
        let mut rng = XorShiftRng::new(seed);
        Self {
            enc1: Linear::new(hidden_dim, input_dim, &mut rng),
            enc2: Linear::new(hidden_dim, hidden_dim, &mut rng),
            heads: Linear::new(max_depth, hidden_dim, &mut rng),
            input_dim,
            hidden_dim,
            max_depth,
            train_loss: f32::NAN,
            train_samples: 0,
        }
    }

    /// Head probabilities `P(accepted ≥ d+1)` for d in `0..max_depth`.
    pub fn head_probs(&self, hidden: &[f32]) -> Vec<f32> {
        debug_assert_eq!(hidden.len(), self.input_dim);
        let mut h1 = Vec::new();
        let mut h2 = Vec::new();
        let mut logits = Vec::new();
        self.enc1.forward(hidden, &mut h1);
        relu_inplace(&mut h1);
        self.enc2.forward(&h1, &mut h2);
        relu_inplace(&mut h2);
        self.heads.forward(&h2, &mut logits);
        logits.iter().map(|&x| sigmoid(x)).collect()
    }

    /// Expected acceptance length (draft tokens only, no bonus).
    pub fn expected_accept_len(&self, hidden: &[f32]) -> f32 {
        self.head_probs(hidden).iter().sum()
    }

    /// The depth decision: grow while the marginal head probability stays
    /// above `threshold`, clamped to `[1, max_depth]`.
    pub fn predict_depth(&self, hidden: &[f32], threshold: f32) -> usize {
        let probs = self.head_probs(hidden);
        let mut d = 0;
        for &p in &probs {
            if p < threshold {
                break;
            }
            d += 1;
        }
        d.clamp(1, self.max_depth)
    }

    /// Trains with Adam on BCE over the heads. Returns the final epoch's
    /// mean loss. Deterministic given `seed`.
    pub fn train(&mut self, data: &[DepthSample], epochs: usize, lr: f32, seed: u64) -> f32 {
        assert!(!data.is_empty());
        let n_params =
            self.enc1.param_len() + self.enc2.param_len() + self.heads.param_len();
        let mut m = vec![0.0f32; n_params];
        let mut v = vec![0.0f32; n_params];
        let mut t = 0usize;
        let mut rng = XorShiftRng::new(seed);
        let mut order: Vec<usize> = (0..data.len()).collect();
        let mut last_loss = 0.0f32;

        for _epoch in 0..epochs {
            // Fisher–Yates shuffle.
            for i in (1..order.len()).rev() {
                order.swap(i, rng.next_range(i + 1));
            }
            let mut epoch_loss = 0.0f64;
            for &idx in &order {
                let s = &data[idx];
                // Forward with intermediates.
                let mut h1 = Vec::new();
                let mut h2 = Vec::new();
                let mut logits = Vec::new();
                self.enc1.forward(&s.hidden, &mut h1);
                let h1_pre = h1.clone();
                relu_inplace(&mut h1);
                self.enc2.forward(&h1, &mut h2);
                let h2_pre = h2.clone();
                relu_inplace(&mut h2);
                self.heads.forward(&h2, &mut logits);

                // BCE loss + dL/dlogit = sigmoid(x) - y.
                let mut dlogits = vec![0.0f32; self.max_depth];
                for d in 0..self.max_depth {
                    let y = if s.accepted >= d + 1 { 1.0 } else { 0.0 };
                    let p = sigmoid(logits[d]);
                    let pc = p.clamp(1e-6, 1.0 - 1e-6);
                    epoch_loss +=
                        -(y * pc.ln() + (1.0 - y) * (1.0 - pc).ln()) as f64;
                    dlogits[d] = p - y;
                }

                // Backward.
                let (mut g1w, mut g1b) =
                    (vec![0.0f32; self.enc1.w.len()], vec![0.0f32; self.enc1.b.len()]);
                let (mut g2w, mut g2b) =
                    (vec![0.0f32; self.enc2.w.len()], vec![0.0f32; self.enc2.b.len()]);
                let (mut ghw, mut ghb) =
                    (vec![0.0f32; self.heads.w.len()], vec![0.0f32; self.heads.b.len()]);
                let mut dh2 = vec![0.0f32; self.hidden_dim];
                let mut dh1 = vec![0.0f32; self.hidden_dim];
                let mut dx = vec![0.0f32; self.input_dim];

                self.heads.backward(&h2, &dlogits, &mut ghw, &mut ghb, &mut dh2);
                for i in 0..self.hidden_dim {
                    if h2_pre[i] <= 0.0 {
                        dh2[i] = 0.0;
                    }
                }
                self.enc2.backward(&h1, &dh2, &mut g2w, &mut g2b, &mut dh1);
                for i in 0..self.hidden_dim {
                    if h1_pre[i] <= 0.0 {
                        dh1[i] = 0.0;
                    }
                }
                self.enc1.backward(&s.hidden, &dh1, &mut g1w, &mut g1b, &mut dx);

                // Adam over the concatenated parameter vector.
                t += 1;
                let b1 = 0.9f32;
                let b2 = 0.999f32;
                let bc1 = 1.0 - b1.powi(t as i32);
                let bc2 = 1.0 - b2.powi(t as i32);
                let mut off = 0usize;
                let mut apply = |p: &mut [f32], g: &[f32]| {
                    for i in 0..p.len() {
                        let j = off + i;
                        m[j] = b1 * m[j] + (1.0 - b1) * g[i];
                        v[j] = b2 * v[j] + (1.0 - b2) * g[i] * g[i];
                        p[i] -= lr * (m[j] / bc1) / ((v[j] / bc2).sqrt() + 1e-8);
                    }
                    off += p.len();
                };
                apply(&mut self.enc1.w, &g1w);
                apply(&mut self.enc1.b, &g1b);
                apply(&mut self.enc2.w, &g2w);
                apply(&mut self.enc2.b, &g2b);
                apply(&mut self.heads.w, &ghw);
                apply(&mut self.heads.b, &ghb);
            }
            last_loss = (epoch_loss / (data.len() * self.max_depth) as f64) as f32;
        }
        self.train_loss = last_loss;
        self.train_samples = data.len();
        last_loss
    }

    /// JSON form (weight file).
    pub fn to_json(&self) -> Json {
        let lin = |l: &Linear| {
            Json::obj(vec![
                ("w", Json::from_f32s(&l.w)),
                ("b", Json::from_f32s(&l.b)),
                ("rows", Json::Num(l.rows as f64)),
                ("cols", Json::Num(l.cols as f64)),
            ])
        };
        Json::obj(vec![
            ("enc1", lin(&self.enc1)),
            ("enc2", lin(&self.enc2)),
            ("heads", lin(&self.heads)),
            ("input_dim", Json::Num(self.input_dim as f64)),
            ("hidden_dim", Json::Num(self.hidden_dim as f64)),
            ("max_depth", Json::Num(self.max_depth as f64)),
            ("train_loss", Json::Num(self.train_loss as f64)),
            ("train_samples", Json::Num(self.train_samples as f64)),
        ])
    }

    /// Parses the JSON weight form.
    pub fn from_json(j: &Json) -> crate::Result<Self> {
        let lin = |j: &Json| -> crate::Result<Linear> {
            let l = Linear {
                w: j.f64_vec("w")?.iter().map(|&x| x as f32).collect(),
                b: j.f64_vec("b")?.iter().map(|&x| x as f32).collect(),
                rows: j.usize("rows")?,
                cols: j.usize("cols")?,
            };
            anyhow::ensure!(l.w.len() == l.rows * l.cols && l.b.len() == l.rows, "bad linear");
            Ok(l)
        };
        Ok(Self {
            enc1: lin(j.req("enc1")?)?,
            enc2: lin(j.req("enc2")?)?,
            heads: lin(j.req("heads")?)?,
            input_dim: j.usize("input_dim")?,
            hidden_dim: j.usize("hidden_dim")?,
            max_depth: j.usize("max_depth")?,
            train_loss: j.f64("train_loss").unwrap_or(f64::NAN) as f32,
            train_samples: j.usize("train_samples").unwrap_or(0),
        })
    }

    /// Writes the weights as JSON.
    pub fn save(&self, path: &std::path::Path) -> crate::Result<()> {
        self.to_json().save(path)
    }

    /// Loads weights from JSON.
    pub fn load(path: &std::path::Path) -> crate::Result<Self> {
        Self::from_json(&Json::parse_file(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Synthetic separable task: direction of the hidden vector determines
    /// the accepted depth.
    fn synthetic(n: usize, dim: usize, dmax: usize, seed: u64) -> Vec<DepthSample> {
        let mut rng = XorShiftRng::new(seed);
        (0..n)
            .map(|_| {
                let cls = rng.next_range(dmax + 1); // accepted depth 0..=dmax
                let mut hidden = vec![0.0f32; dim];
                for h in hidden.iter_mut() {
                    *h = rng.next_f32() * 0.2 - 0.1;
                }
                // Embed the class as a strong signal on two coordinates.
                hidden[0] = cls as f32 / dmax as f32;
                hidden[1] = 1.0 - hidden[0];
                DepthSample { hidden, accepted: cls }
            })
            .collect()
    }

    #[test]
    fn untrained_outputs_are_probabilities() {
        let p = DepthPredictor::new(16, 8, 6, 0);
        let probs = p.head_probs(&vec![0.1; 16]);
        assert_eq!(probs.len(), 6);
        assert!(probs.iter().all(|&x| (0.0..=1.0).contains(&x)));
    }

    #[test]
    fn training_reduces_loss_and_learns_signal() {
        let data = synthetic(400, 16, 4, 3);
        let mut p = DepthPredictor::new(16, 16, 4, 1);
        let l0 = p.train(&data, 1, 1e-3, 9);
        let l1 = p.train(&data, 8, 1e-3, 10);
        assert!(l1 < l0, "loss should fall: {l0} -> {l1}");

        // Expected length must track the planted class.
        let lo = DepthSample { hidden: { let mut h = vec![0.0; 16]; h[0] = 0.0; h[1] = 1.0; h }, accepted: 0 };
        let hi = DepthSample { hidden: { let mut h = vec![0.0; 16]; h[0] = 1.0; h[1] = 0.0; h }, accepted: 4 };
        assert!(
            p.expected_accept_len(&hi.hidden) > p.expected_accept_len(&lo.hidden) + 1.0,
            "hi {} vs lo {}",
            p.expected_accept_len(&hi.hidden),
            p.expected_accept_len(&lo.hidden)
        );
    }

    #[test]
    fn predict_depth_clamps_to_valid_range() {
        let p = DepthPredictor::new(8, 8, 5, 2);
        let d = p.predict_depth(&vec![0.0; 8], 0.5);
        assert!((1..=5).contains(&d));
    }

    #[test]
    fn deterministic_training() {
        let data = synthetic(100, 8, 3, 1);
        let mut a = DepthPredictor::new(8, 8, 3, 7);
        let mut b = DepthPredictor::new(8, 8, 3, 7);
        let la = a.train(&data, 2, 1e-3, 5);
        let lb = b.train(&data, 2, 1e-3, 5);
        assert_eq!(la, lb);
        assert_eq!(a.head_probs(&data[0].hidden), b.head_probs(&data[0].hidden));
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("ygg_pred_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("pred.json");
        let p = DepthPredictor::new(8, 4, 3, 9);
        p.save(&path).unwrap();
        let q = DepthPredictor::load(&path).unwrap();
        let x = vec![0.3; 8];
        assert_eq!(p.head_probs(&x), q.head_probs(&x));
    }
}
