//! Baseline engines (§7 comparisons).
//!
//! All speculative baselines are [`crate::engine::SpecDecoder`] presets
//! (see [`crate::config::EngineConfig`]); this module adds the
//! non-speculative [`VanillaEngine`] floor and a factory that builds every
//! engine of the paper's comparison matrix by name.

use std::time::Instant;

use crate::config::EngineConfig;
use crate::engine::{Engine, Generation, SpecDecoder, Session};
use crate::metrics::Recorder;
use crate::objective::LatencyModel;
use crate::runtime::Runtime;

/// Plain autoregressive decoding with the verifier model (no speculation):
/// the latency floor every speculative system is compared against
/// (`T_generative` in Eq. 2).
pub struct VanillaEngine {
    rt: Runtime,
    pub target: String,
    pub compiled: bool,
    pub seed: u64,
}

impl VanillaEngine {
    pub fn new(rt: &Runtime, target: &str, compiled: bool) -> Self {
        // Decode (w1) + the prefill chunk widths; avoids mid-run compiles.
        let _ = rt.precompile(target, &[1, 16, 32, 64]);
        Self { rt: rt.clone(), target: target.to_string(), compiled, seed: 0 }
    }
}

impl Engine for VanillaEngine {
    fn name(&self) -> String {
        format!("vanilla[{}|{}]", self.target, if self.compiled { "compiled" } else { "eager" })
    }

    fn generate_with(
        &mut self,
        prompt: &[u32],
        max_new: usize,
        sink: crate::engine::TokenSink,
    ) -> crate::Result<Generation> {
        // A Session needs a drafter side; reuse the target as a stand-in
        // (its cache stays untouched: we never call the drafter).
        let mut sess = Session::new(&self.rt, &self.target, &self.target, self.seed, self.compiled)?;
        let t_prefill = Instant::now();
        sess.prefill(prompt)?;
        let prefill_seconds = t_prefill.elapsed().as_secs_f64();

        let mut rec = Recorder::new();
        let mut tokens = Vec::new();
        let t0 = Instant::now();
        let mut cur = *sess.committed.last().unwrap();
        let mut pos = (sess.committed_len() - 1) as i32;
        while tokens.len() < max_new && sess.target.slots.free_count() > 1 {
            let slot = sess.target.slots.alloc(1).unwrap()[0];
            let tree = crate::tree::TokenTree::new(cur);
            let mask = sess
                .target
                .slots
                .mask_builder()
                .build(&tree, &[0], &[Some(slot)], 1)
                .to_vec();
            let req = sess
                .target
                .padded_request(1, &[cur], &[pos], &[slot], &mask, sess.exec_mode());
            let t_it = Instant::now();
            let reply = sess.rt.forward(req)?;
            rec.record("stage.iter", t_it.elapsed().as_secs_f64());
            sess.target.slots.commit(slot);
            let logits = &reply.logits[..sess.target.spec.vocab];
            let next = if self.seed == 0 && true {
                // temperature handled by callers via seed/temp on SpecDecoder;
                // vanilla is greedy (the Eq. 2 reference uses greedy too).
                crate::sampling::argmax(logits) as u32
            } else {
                crate::sampling::argmax(logits) as u32
            };
            sink(&[next]);
            tokens.push(next);
            sess.committed.push(next);
            cur = next;
            pos += 1;
        }
        let seconds = t0.elapsed().as_secs_f64();
        Ok(Generation {
            iterations: tokens.len(),
            tokens,
            seconds,
            prefill_seconds,
            recorder: rec,
        })
    }
}

/// Engine factory for the comparison matrix. Names match the paper's
/// baselines; `pair` is (drafter, target).
pub fn build_engine(
    rt: &Runtime,
    name: &str,
    pair: (&str, &str),
    lat: &LatencyModel,
) -> crate::Result<Box<dyn Engine>> {
    let (drafter, target) = pair;
    let base = |mut cfg: EngineConfig| -> EngineConfig {
        cfg.drafter = drafter.to_string();
        cfg.target = target.to_string();
        cfg
    };
    Ok(match name {
        "vanilla" => Box::new(VanillaEngine::new(rt, target, true)),
        "vanilla-eager" => Box::new(VanillaEngine::new(rt, target, false)),
        "seqspec" => Box::new(SpecDecoder::new(rt, base(EngineConfig::preset_seqspec(5)), lat.clone(), None)),
        "specinfer" => Box::new(SpecDecoder::new(
            rt,
            base(EngineConfig::preset_specinfer(4, 4, 64)),
            lat.clone(),
            None,
        )),
        "sequoia" => Box::new(SpecDecoder::new(rt, base(EngineConfig::preset_sequoia(32)), lat.clone(), None)),
        "vllmspec" => Box::new(SpecDecoder::new(rt, base(EngineConfig::preset_vllmspec(5)), lat.clone(), None)),
        "yggdrasil" => Box::new(SpecDecoder::new(rt, base(EngineConfig::default()), lat.clone(), None)),
        _ => anyhow::bail!("unknown engine '{name}'"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    #[test]
    fn factory_knows_all_paper_baselines() {
        let dir = Path::new("artifacts");
        if !(dir.join("manifest.json").exists() && dir.join("dft-xs.weights.bin").exists() && dir.join("tgt-lg.weights.bin").exists()) {
            return;
        }
        let rt = Runtime::load(dir, &["dft-xs", "tgt-sm"]).unwrap();
        let lat = crate::objective::LatencyModel {
            drafter: crate::objective::LatencyCurve::new(&[(1, 1e-3)]),
            verifier: crate::objective::LatencyCurve::new(&[(1, 5e-3)]),
            cpu_overhead: 1e-4,
        };
        for name in ["vanilla", "seqspec", "specinfer", "sequoia", "vllmspec", "yggdrasil"] {
            let e = build_engine(&rt, name, ("dft-xs", "tgt-sm"), &lat).unwrap();
            assert!(!e.name().is_empty());
        }
        assert!(build_engine(&rt, "nope", ("dft-xs", "tgt-sm"), &lat).is_err());
    }
}
