//! Baseline engines (§7 comparisons).
//!
//! All speculative baselines are [`crate::engine::SpecDecoder`] presets
//! (see [`crate::config::EngineConfig`]); this module adds the
//! non-speculative [`VanillaEngine`] floor and a factory that builds every
//! engine of the paper's comparison matrix by name.
//!
//! Like the speculative engine, `VanillaEngine` is step-driven: one
//! [`DecodeTask`] step decodes exactly one token, so vanilla baselines
//! interleave under the multi-session server exactly like speculative
//! sessions do and stay comparable in the serving benchmarks.

use std::time::Instant;

use crate::config::EngineConfig;
use crate::engine::{
    drive, DecodeTask, Engine, Generation, Session, SpecDecoder, StepEngine, StepOutcome,
    TaskState,
};
use crate::metrics::Recorder;
use crate::objective::LatencyModel;
use crate::runtime::Runtime;

/// Plain autoregressive decoding with the verifier model (no speculation):
/// the latency floor every speculative system is compared against
/// (`T_generative` in Eq. 2).
pub struct VanillaEngine {
    rt: Runtime,
    /// Verifier model name (the engine decodes with it directly).
    pub target: String,
    /// Resident-weights compiled execution vs per-call restaging.
    pub compiled: bool,
    /// RNG seed for new sessions (greedy decoding ignores it).
    pub seed: u64,
}

impl VanillaEngine {
    /// Builds the engine and precompiles its decode/prefill widths.
    pub fn new(rt: &Runtime, target: &str, compiled: bool) -> Self {
        // Decode (w1) + the prefill chunk widths; avoids mid-run compiles.
        let _ = rt.precompile(target, &[1, 16, 32, 64]);
        Self { rt: rt.clone(), target: target.to_string(), compiled, seed: 0 }
    }
}

/// One resumable vanilla generation: one token per `step()`.
pub struct VanillaTask {
    sess: Session,
    state: TaskState,
    prompt: Vec<u32>,
    max_new: usize,
    cur: u32,
    pos: i32,
    tokens: Vec<u32>,
    rec: Recorder,
    seconds: f64,
    prefill_seconds: f64,
}

impl VanillaTask {
    fn step_prefill(&mut self) -> crate::Result<StepOutcome> {
        let prompt = std::mem::take(&mut self.prompt);
        let t_prefill = Instant::now();
        self.sess.prefill(&prompt)?;
        self.prefill_seconds = t_prefill.elapsed().as_secs_f64();
        self.cur = *self.sess.committed.last().unwrap();
        self.pos = (self.sess.committed_len() - 1) as i32;
        self.state = if self.max_new > 0 && self.sess.target.slots.free_count() > 1 {
            TaskState::Iterate
        } else {
            TaskState::Done
        };
        Ok(StepOutcome { tokens: vec![], state: self.state })
    }

    fn step_iterate(&mut self) -> crate::Result<StepOutcome> {
        let t_it = Instant::now();
        let slot = self.sess.target.slots.alloc(1).unwrap()[0];
        let tree = crate::tree::TokenTree::new(self.cur);
        let mask = self
            .sess
            .target
            .slots
            .mask_builder()
            .build(&tree, &[0], &[Some(slot)], 1)
            .to_vec();
        let req = self.sess.target.padded_request(
            1,
            &[self.cur],
            &[self.pos],
            &[slot],
            &mask,
            self.sess.exec_mode(),
        );
        let reply = self.sess.rt.forward(req)?;
        self.rec.record("stage.iter", t_it.elapsed().as_secs_f64());
        self.sess.target.slots.commit(slot);
        let logits = &reply.logits[..self.sess.target.spec.vocab];
        // Vanilla is greedy (the Eq. 2 reference uses greedy too).
        let next = crate::sampling::argmax(logits) as u32;
        self.tokens.push(next);
        self.sess.committed.push(next);
        self.cur = next;
        self.pos += 1;
        self.seconds += t_it.elapsed().as_secs_f64();
        if self.tokens.len() >= self.max_new || self.sess.target.slots.free_count() <= 1 {
            self.state = TaskState::Done;
        }
        Ok(StepOutcome { tokens: vec![next], state: self.state })
    }
}

impl DecodeTask for VanillaTask {
    fn state(&self) -> TaskState {
        self.state
    }

    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }

    fn step(&mut self) -> crate::Result<StepOutcome> {
        match self.state {
            TaskState::Done => Ok(StepOutcome { tokens: vec![], state: TaskState::Done }),
            TaskState::Prefill => self.step_prefill(),
            TaskState::Iterate => self.step_iterate(),
        }
    }

    fn headroom(&self) -> usize {
        self.sess.headroom(1)
    }

    fn kv_slots_in_use(&self) -> usize {
        self.sess.drafter.slots.in_use() + self.sess.target.slots.in_use()
    }

    fn finish(self: Box<Self>) -> Generation {
        let mut this = *self;
        Generation {
            iterations: this.tokens.len(),
            tokens: std::mem::take(&mut this.tokens),
            seconds: this.seconds,
            prefill_seconds: this.prefill_seconds,
            recorder: std::mem::take(&mut this.rec),
        }
    }
}

impl StepEngine for VanillaEngine {
    fn begin(&mut self, prompt: &[u32], max_new: usize) -> crate::Result<Box<dyn DecodeTask>> {
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");
        // A Session needs a drafter side; reuse the target as a stand-in
        // (its cache stays untouched: we never call the drafter).
        let sess =
            Session::new(&self.rt, &self.target, &self.target, self.seed, self.compiled)?;
        Ok(Box::new(VanillaTask {
            sess,
            state: TaskState::Prefill,
            prompt: prompt.to_vec(),
            max_new,
            cur: 0,
            pos: 0,
            tokens: Vec::new(),
            rec: Recorder::new(),
            seconds: 0.0,
            prefill_seconds: 0.0,
        }))
    }
}

impl Engine for VanillaEngine {
    fn name(&self) -> String {
        format!("vanilla[{}|{}]", self.target, if self.compiled { "compiled" } else { "eager" })
    }

    fn generate_with(
        &mut self,
        prompt: &[u32],
        max_new: usize,
        sink: crate::engine::TokenSink,
    ) -> crate::Result<Generation> {
        let task = self.begin(prompt, max_new)?;
        drive(task, sink)
    }
}

/// Engine factory for the comparison matrix. Names match the paper's
/// baselines; `pair` is (drafter, target).
pub fn build_engine(
    rt: &Runtime,
    name: &str,
    pair: (&str, &str),
    lat: &LatencyModel,
) -> crate::Result<Box<dyn Engine>> {
    let (drafter, target) = pair;
    let base = |mut cfg: EngineConfig| -> EngineConfig {
        cfg.drafter = drafter.to_string();
        cfg.target = target.to_string();
        cfg
    };
    Ok(match name {
        "vanilla" => Box::new(VanillaEngine::new(rt, target, true)),
        "vanilla-eager" => Box::new(VanillaEngine::new(rt, target, false)),
        "seqspec" => Box::new(SpecDecoder::new(rt, base(EngineConfig::preset_seqspec(5)), lat.clone(), None)),
        "specinfer" => Box::new(SpecDecoder::new(
            rt,
            base(EngineConfig::preset_specinfer(4, 4, 64)),
            lat.clone(),
            None,
        )),
        "sequoia" => Box::new(SpecDecoder::new(rt, base(EngineConfig::preset_sequoia(32)), lat.clone(), None)),
        "vllmspec" => Box::new(SpecDecoder::new(rt, base(EngineConfig::preset_vllmspec(5)), lat.clone(), None)),
        "yggdrasil" => Box::new(SpecDecoder::new(rt, base(EngineConfig::default()), lat.clone(), None)),
        _ => anyhow::bail!("unknown engine '{name}'"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    #[test]
    fn factory_knows_all_paper_baselines() {
        let dir = Path::new("artifacts");
        if !(dir.join("manifest.json").exists() && dir.join("dft-xs.weights.bin").exists() && dir.join("tgt-lg.weights.bin").exists()) {
            return;
        }
        let rt = Runtime::load(dir, &["dft-xs", "tgt-sm"]).unwrap();
        let lat = crate::objective::LatencyModel {
            drafter: crate::objective::LatencyCurve::new(&[(1, 1e-3)]),
            verifier: crate::objective::LatencyCurve::new(&[(1, 5e-3)]),
            cpu_overhead: 1e-4,
        };
        for name in ["vanilla", "seqspec", "specinfer", "sequoia", "vllmspec", "yggdrasil"] {
            let e = build_engine(&rt, name, ("dft-xs", "tgt-sm"), &lat).unwrap();
            assert!(!e.name().is_empty());
        }
        assert!(build_engine(&rt, "nope", ("dft-xs", "tgt-sm"), &lat).is_err());
    }
}
