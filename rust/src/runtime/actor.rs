//! The device thread: sole owner of the PJRT client, compiled executables,
//! resident weight buffers and KV-cache buffers.
//!
//! Requests arrive over an mpsc channel and execute FIFO. Each forward:
//!
//! 1. stages the small host inputs (tokens/positions/slots/mask) to device
//!    buffers,
//! 2. runs `execute_b_untuple` with `[inputs…, cache, weights…]`,
//! 3. downloads logits + hidden to host, and swaps the cache entry to the
//!    freshly-returned buffer (zero-copy threading).
//!
//! Graphs compile lazily per (model, width) from the HLO text in the
//! artifacts directory — `HloModuleProto::from_text_file` → `compile` —
//! and stay cached for the process lifetime (the "static runtime" the
//! paper pairs with the Equal-Growth Tree).

use std::collections::HashMap;
use std::sync::mpsc::{Receiver, Sender};
use std::time::Instant;

use xla::{HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::{CacheId, ForwardReply, ForwardRequest, Manifest, Msg};

struct LoadedModel {
    name: String,
    spec: super::ModelSpec,
    /// Resident weight buffers in manifest tensor order.
    weights: Vec<PjRtBuffer>,
    /// Host copies (only used by `ExecMode::WeightsByValue` restaging).
    weights_host: Vec<(super::TensorSpec, Vec<f32>)>,
    execs: HashMap<usize, PjRtLoadedExecutable>,
}

struct Actor {
    client: PjRtClient,
    manifest: Manifest,
    models: Vec<LoadedModel>,
    caches: HashMap<CacheId, PjRtBuffer>,
    next_cache: CacheId,
}

pub(crate) fn run(
    manifest: Manifest,
    model_names: Vec<String>,
    rx: Receiver<Msg>,
    ready: Sender<crate::Result<()>>,
) {
    let mut actor = match Actor::new(manifest, &model_names) {
        Ok(a) => {
            let _ = ready.send(Ok(()));
            a
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    while let Ok(msg) = rx.recv() {
        match msg {
            Msg::Forward { req, tx } => {
                let _ = tx.send(actor.forward(req));
            }
            Msg::NewCache { model, tx } => {
                let _ = tx.send(actor.new_cache(&model));
            }
            Msg::DropCache { id } => {
                actor.caches.remove(&id);
            }
            Msg::Precompile { model, widths, tx } => {
                let _ = tx.send(actor.precompile(&model, &widths));
            }
            Msg::ColdCompile { model, width, tx } => {
                let _ = tx.send(actor.cold_compile(&model, width));
            }
            Msg::Shutdown => break,
        }
    }
}

impl Actor {
    fn new(manifest: Manifest, model_names: &[String]) -> crate::Result<Self> {
        let client = PjRtClient::cpu().map_err(to_anyhow)?;
        let mut models = Vec::new();
        for name in model_names {
            let spec = manifest.model(name)?.clone();
            let weights_host = manifest.load_weights(name)?;
            let mut weights = Vec::with_capacity(weights_host.len());
            for (t, data) in &weights_host {
                weights.push(
                    client
                        .buffer_from_host_buffer(data, &t.shape, None)
                        .map_err(to_anyhow)?,
                );
            }
            models.push(LoadedModel {
                name: name.clone(),
                spec,
                weights,
                weights_host,
                execs: HashMap::new(),
            });
        }
        Ok(Self { client, manifest, models, caches: HashMap::new(), next_cache: 1 })
    }

    fn model_idx(&self, name: &str) -> crate::Result<usize> {
        self.models
            .iter()
            .position(|m| m.name == name)
            .ok_or_else(|| anyhow::anyhow!("model '{name}' not loaded in this runtime"))
    }

    fn compile_width(&mut self, mi: usize, width: usize) -> crate::Result<f64> {
        if self.models[mi].execs.contains_key(&width) {
            return Ok(0.0);
        }
        let t0 = Instant::now();
        let exe = self.compile_fresh(mi, width)?;
        let dt = t0.elapsed().as_secs_f64();
        self.models[mi].execs.insert(width, exe);
        Ok(dt)
    }

    fn compile_fresh(&self, mi: usize, width: usize) -> crate::Result<PjRtLoadedExecutable> {
        let m = &self.models[mi];
        let file = m
            .spec
            .graph_file(width)
            .ok_or_else(|| anyhow::anyhow!("{}: no graph for width {width}", m.name))?;
        let path = self.manifest.dir.join(file);
        let proto = HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )
        .map_err(to_anyhow)?;
        let comp = XlaComputation::from_proto(&proto);
        self.client.compile(&comp).map_err(to_anyhow)
    }

    fn new_cache(&mut self, model: &str) -> crate::Result<CacheId> {
        let mi = self.model_idx(model)?;
        let spec = &self.models[mi].spec;
        let zeros = vec![0f32; spec.cache_numel()];
        let buf = self
            .client
            .buffer_from_host_buffer(&zeros, &spec.cache_dims(), None)
            .map_err(to_anyhow)?;
        let id = self.next_cache;
        self.next_cache += 1;
        self.caches.insert(id, buf);
        Ok(id)
    }

    fn precompile(&mut self, model: &str, widths: &[usize]) -> crate::Result<Vec<(usize, f64)>> {
        let mi = self.model_idx(model)?;
        widths
            .iter()
            .map(|&w| Ok((w, self.compile_width(mi, w)?)))
            .collect()
    }

    fn cold_compile(&mut self, model: &str, width: usize) -> crate::Result<f64> {
        let mi = self.model_idx(model)?;
        let t0 = Instant::now();
        let _exe = self.compile_fresh(mi, width)?;
        Ok(t0.elapsed().as_secs_f64())
    }

    fn forward(&mut self, req: ForwardRequest) -> crate::Result<ForwardReply> {
        let mi = self.model_idx(&req.model)?;
        self.compile_width(mi, req.width)?;
        let m = &self.models[mi];
        let spec = &m.spec;
        let w = req.width;
        let c = spec.cache_capacity;
        anyhow::ensure!(req.tokens.len() == w, "tokens len {} != width {w}", req.tokens.len());
        anyhow::ensure!(req.positions.len() == w && req.slots.len() == w, "positions/slots len");
        anyhow::ensure!(req.mask.len() == w * c, "mask len {} != {}", req.mask.len(), w * c);
        let cache_buf = self
            .caches
            .get(&req.cache)
            .ok_or_else(|| anyhow::anyhow!("unknown cache id {}", req.cache))?;

        // Stage the small per-call inputs.
        let t_stage = Instant::now();
        let tokens = self
            .client
            .buffer_from_host_buffer(&req.tokens, &[w], None)
            .map_err(to_anyhow)?;
        let positions = self
            .client
            .buffer_from_host_buffer(&req.positions, &[w], None)
            .map_err(to_anyhow)?;
        let slots = self
            .client
            .buffer_from_host_buffer(&req.slots, &[w], None)
            .map_err(to_anyhow)?;
        let mask = self
            .client
            .buffer_from_host_buffer(&req.mask, &[w, c], None)
            .map_err(to_anyhow)?;

        // Weights: resident buffers, or restaged per call in the eager-
        // runtime comparison mode.
        let restaged: Vec<PjRtBuffer>;
        let weight_refs: Vec<&PjRtBuffer> = match req.mode {
            super::ExecMode::Resident => m.weights.iter().collect(),
            super::ExecMode::WeightsByValue => {
                restaged = m
                    .weights_host
                    .iter()
                    .map(|(t, data)| {
                        self.client
                            .buffer_from_host_buffer(data, &t.shape, None)
                            .map_err(to_anyhow)
                    })
                    .collect::<crate::Result<_>>()?;
                restaged.iter().collect()
            }
        };
        let stage_seconds = t_stage.elapsed().as_secs_f64();

        let mut args: Vec<&PjRtBuffer> = Vec::with_capacity(5 + weight_refs.len());
        args.push(&tokens);
        args.push(&positions);
        args.push(&slots);
        args.push(&mask);
        args.push(cache_buf);
        args.extend(weight_refs);

        let exe = &m.execs[&w];
        let t_exec = Instant::now();
        let mut outs = exe.execute_b_untuple(&args).map_err(to_anyhow)?;
        let exec_seconds = t_exec.elapsed().as_secs_f64();

        let mut replica = outs.swap_remove(0);
        anyhow::ensure!(replica.len() == 3, "expected 3 outputs, got {}", replica.len());
        let new_cache = replica.pop().unwrap();
        let hidden_buf = replica.pop().unwrap();
        let logits_buf = replica.pop().unwrap();

        let logits = to_host_f32(&logits_buf)?;
        let hidden = to_host_f32(&hidden_buf)?;
        anyhow::ensure!(logits.len() == w * spec.vocab, "logits size");

        // Thread the cache: the output buffer replaces the input in place.
        self.caches.insert(req.cache, new_cache);

        Ok(ForwardReply { logits, hidden, stage_seconds, exec_seconds })
    }
}

fn to_host_f32(buf: &PjRtBuffer) -> crate::Result<Vec<f32>> {
    let lit: Literal = buf.to_literal_sync().map_err(to_anyhow)?;
    lit.to_vec::<f32>().map_err(to_anyhow)
}

fn to_anyhow(e: xla::Error) -> anyhow::Error {
    anyhow::anyhow!("{e}")
}
