//! PJRT runtime: loads the AOT artifacts and executes them on the CPU
//! PJRT client from a dedicated *device thread*.
//!
//! The `xla` crate's PJRT wrappers hold raw pointers and are not `Send`,
//! which matches how a real accelerator is driven: one submission thread
//! owns the device. [`Runtime::load`] spawns that thread ([`actor`]); the
//! cloneable [`Runtime`] handle submits work through a channel and receives
//! completions through per-request channels. Submission is non-blocking —
//! this is what the stage scheduler (§5) exploits to overlap CPU
//! bookkeeping with model execution, and queued requests execute FIFO,
//! preserving single-accelerator semantics.
//!
//! Buffer residency: model weights are uploaded once at load; KV caches
//! live on the device as [`CacheId`]-addressed buffers and are threaded
//! from one call into the next via the vendored `execute_b_untuple` (no
//! host round-trip — see `vendor/xla`). Only the small per-call inputs
//! (tokens/positions/slots/mask) and the logits/hidden outputs cross the
//! host boundary.

pub mod actor;
pub mod manifest;

pub use manifest::{Manifest, ModelSpec, TensorSpec};

use std::sync::mpsc;
use std::sync::Arc;

/// Handle to a device-resident KV cache.
pub type CacheId = u64;

/// How a forward call treats weights/executables — the Fig. 4 runtime
/// comparison axes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// Static compiled graph + resident weight buffers (the Yggdrasil way;
    /// CUDA-Graph/TorchInductor analog).
    Resident,
    /// Static compiled graph, but weights are re-staged from host every
    /// call (eager-runtime analog: no buffer residency).
    WeightsByValue,
}

/// One forward call against a model graph of compiled width `width`.
#[derive(Debug, Clone)]
pub struct ForwardRequest {
    /// Model name in the manifest.
    pub model: String,
    /// Compiled graph width (row count of the padded batch).
    pub width: usize,
    /// Device cache the call reads/writes.
    pub cache: CacheId,
    /// `width` token ids (padding rows are 0).
    pub tokens: Vec<i32>,
    /// `width` RoPE positions.
    pub positions: Vec<i32>,
    /// `width` cache slots to scatter K/V into (padding → trash).
    pub slots: Vec<i32>,
    /// Row-major `[width, cache_capacity]` validity mask.
    pub mask: Vec<f32>,
    /// Weights-resident vs restaged execution.
    pub mode: ExecMode,
}

/// Completed forward call.
#[derive(Debug, Clone)]
pub struct ForwardReply {
    /// Row-major `[width, vocab]`.
    pub logits: Vec<f32>,
    /// Row-major `[width, d_model]` (final-norm hidden states; feeds the
    /// depth predictor).
    pub hidden: Vec<f32>,
    /// Seconds spent staging host inputs to device buffers.
    pub stage_seconds: f64,
    /// Seconds inside `execute` (the "GPU time" analog).
    pub exec_seconds: f64,
}

pub(crate) enum Msg {
    Forward {
        req: ForwardRequest,
        tx: mpsc::Sender<crate::Result<ForwardReply>>,
    },
    NewCache {
        model: String,
        tx: mpsc::Sender<crate::Result<CacheId>>,
    },
    DropCache {
        id: CacheId,
    },
    Precompile {
        model: String,
        widths: Vec<usize>,
        tx: mpsc::Sender<crate::Result<Vec<(usize, f64)>>>,
    },
    /// Compiles the width graph from scratch and throws the executable
    /// away — the "dynamic shapes force recompilation" cost of Fig. 4.
    ColdCompile {
        model: String,
        width: usize,
        tx: mpsc::Sender<crate::Result<f64>>,
    },
    Shutdown,
}

/// In-flight call; `wait()` blocks for the reply.
pub struct Pending<T> {
    rx: mpsc::Receiver<crate::Result<T>>,
}

impl<T> Pending<T> {
    /// Blocks for the reply.
    pub fn wait(self) -> crate::Result<T> {
        self.rx.recv().map_err(|_| anyhow::anyhow!("device thread terminated"))?
    }

    /// Non-blocking poll; returns `None` while still executing.
    pub fn try_wait(&self) -> Option<crate::Result<T>> {
        match self.rx.try_recv() {
            Ok(r) => Some(r),
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => {
                Some(Err(anyhow::anyhow!("device thread terminated")))
            }
        }
    }
}

struct Shared {
    tx: mpsc::Sender<Msg>,
    manifest: Manifest,
    join: std::sync::Mutex<Option<std::thread::JoinHandle<()>>>,
}

/// Cloneable handle to the device thread.
#[derive(Clone)]
pub struct Runtime {
    shared: Arc<Shared>,
}

impl Runtime {
    /// Loads `models` from `artifacts_dir`, uploads their weights, and
    /// spawns the device thread. Graphs compile lazily per width on first
    /// use (or eagerly via [`Runtime::precompile`]).
    pub fn load(artifacts_dir: &std::path::Path, models: &[&str]) -> crate::Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        for m in models {
            manifest.model(m)?; // fail fast on unknown names
        }
        let (tx, rx) = mpsc::channel();
        let names: Vec<String> = models.iter().map(|s| s.to_string()).collect();
        let mf = manifest.clone();
        let (ready_tx, ready_rx) = mpsc::channel();
        let join = std::thread::Builder::new()
            .name("pjrt-device".into())
            .spawn(move || actor::run(mf, names, rx, ready_tx))?;
        // Surface startup errors (client creation, weight upload) here.
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("device thread died during startup"))??;
        Ok(Self {
            shared: Arc::new(Shared { tx, manifest, join: std::sync::Mutex::new(Some(join)) }),
        })
    }

    /// The loaded artifact manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.shared.manifest
    }

    /// Model spec by name.
    pub fn spec(&self, model: &str) -> crate::Result<&ModelSpec> {
        self.shared.manifest.model(model)
    }

    /// Allocates a zeroed device cache for `model`.
    pub fn new_cache(&self, model: &str) -> crate::Result<CacheId> {
        let (tx, rx) = mpsc::channel();
        self.send(Msg::NewCache { model: model.into(), tx })?;
        Pending { rx }.wait()
    }

    /// Frees a device cache (fire-and-forget).
    pub fn drop_cache(&self, id: CacheId) {
        let _ = self.send(Msg::DropCache { id });
    }

    /// Non-blocking submission; execution order is submission order.
    pub fn submit(&self, req: ForwardRequest) -> crate::Result<Pending<ForwardReply>> {
        let (tx, rx) = mpsc::channel();
        self.send(Msg::Forward { req, tx })?;
        Ok(Pending { rx })
    }

    /// Blocking convenience wrapper.
    pub fn forward(&self, req: ForwardRequest) -> crate::Result<ForwardReply> {
        self.submit(req)?.wait()
    }

    /// Eagerly compiles the given widths; returns (width, compile_seconds).
    pub fn precompile(&self, model: &str, widths: &[usize]) -> crate::Result<Vec<(usize, f64)>> {
        let (tx, rx) = mpsc::channel();
        self.send(Msg::Precompile { model: model.into(), widths: widths.to_vec(), tx })?;
        Pending { rx }.wait()
    }

    /// Fresh compilation cost of one width graph (Fig. 4's recompile bar).
    pub fn cold_compile_seconds(&self, model: &str, width: usize) -> crate::Result<f64> {
        let (tx, rx) = mpsc::channel();
        self.send(Msg::ColdCompile { model: model.into(), width, tx })?;
        Pending { rx }.wait()
    }

    /// Measures mean wall seconds per forward at `width` over `reps` calls
    /// (after `warmup` discarded calls) using a scratch cache.
    pub fn profile_width(
        &self,
        model: &str,
        width: usize,
        reps: usize,
        warmup: usize,
        mode: ExecMode,
    ) -> crate::Result<f64> {
        let spec = self.spec(model)?.clone();
        let cache = self.new_cache(model)?;
        let mut mask = vec![0f32; width * spec.cache_capacity];
        for r in 0..width {
            // attend to self only — representative sparse mask
            mask[r * spec.cache_capacity + r] = 1.0;
        }
        let mk = |cache| ForwardRequest {
            model: model.into(),
            width,
            cache,
            tokens: vec![1; width],
            positions: (0..width as i32).collect(),
            slots: (0..width as i32).collect(),
            mask: mask.clone(),
            mode,
        };
        for _ in 0..warmup {
            self.forward(mk(cache))?;
        }
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            self.forward(mk(cache))?;
        }
        let dt = t0.elapsed().as_secs_f64() / reps.max(1) as f64;
        self.drop_cache(cache);
        Ok(dt)
    }

    fn send(&self, msg: Msg) -> crate::Result<()> {
        self.shared
            .tx
            .send(msg)
            .map_err(|_| anyhow::anyhow!("device thread terminated"))
    }
}

impl Drop for Shared {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.lock().unwrap().take() {
            let _ = j.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Batch-width planning (cross-session batched verification, DESIGN.md §9)
// ---------------------------------------------------------------------------

/// One packed device call of a batched scheduling round: which sessions'
/// verify rows ride together and which compiled graph width hosts them.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BatchGroup {
    /// Indices (into the planner's input) of the sessions in this batch.
    pub members: Vec<usize>,
    /// Total real (non-padding) rows across the members.
    pub rows: usize,
    /// Compiled graph width the batch pads to (smallest fitting
    /// [`crate::config::GRAPH_WIDTHS`] entry).
    pub width: usize,
}

/// Packs per-session verify-row counts into device batches.
///
/// Greedy first-fit in session order: sessions accumulate into a group
/// while the total stays within `max_width` (the largest compiled graph
/// width); overflow starts the next group. Each group then pads to the
/// smallest compiled width that fits its rows, so one scheduling round
/// costs `groups.len()` verifier calls instead of `rows.len()`.
///
/// Panics if any single session needs more rows than `max_width` — the
/// engine's pruning stage guarantees per-session trees fit one graph.
pub fn plan_batches(rows: &[usize], max_width: usize) -> Vec<BatchGroup> {
    let mut groups: Vec<BatchGroup> = Vec::new();
    let mut cur = BatchGroup { members: Vec::new(), rows: 0, width: 0 };
    for (i, &r) in rows.iter().enumerate() {
        assert!(r > 0, "session {i} contributes zero rows");
        assert!(r <= max_width, "session {i} rows {r} exceed max width {max_width}");
        if cur.rows + r > max_width && !cur.members.is_empty() {
            groups.push(cur);
            cur = BatchGroup { members: Vec::new(), rows: 0, width: 0 };
        }
        cur.members.push(i);
        cur.rows += r;
    }
    if !cur.members.is_empty() {
        groups.push(cur);
    }
    for g in &mut groups {
        g.width = crate::config::width_for(g.rows)
            .expect("group rows bounded by max_width, which is a compiled width");
    }
    groups
}

/// [`plan_batches`] with a *call envelope*: every group pads to at least
/// the compiled width covering `envelope` rows (itself clamped to
/// `max_width`).
///
/// The draft stages of stage-aligned batched drafting (DESIGN.md §11)
/// need this: a round's packed level shrinks as sessions' trees finish
/// growing, so naive tight padding would bounce one logical stream of
/// calls across several compiled widths round after round. Pinning the
/// floor to the steady-state envelope (`sessions × draft width`) keeps
/// the padded shape static — one graph serves every level call — at the
/// cost of a few inert padding rows. `envelope == 0` degenerates to
/// [`plan_batches`].
pub fn plan_batches_enveloped(
    rows: &[usize],
    max_width: usize,
    envelope: usize,
) -> Vec<BatchGroup> {
    let mut groups = plan_batches(rows, max_width);
    if envelope > 0 {
        let floor = crate::config::width_for(envelope.min(max_width)).unwrap_or(max_width);
        for g in &mut groups {
            g.width = g.width.max(floor);
        }
    }
    groups
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_batches_packs_within_max_width() {
        let g = plan_batches(&[10, 20, 30, 5], 64);
        assert_eq!(g.len(), 2, "10+20+30 fits 64; 5 overflows");
        assert_eq!(g[0].members, vec![0, 1, 2]);
        assert_eq!(g[0].rows, 60);
        assert_eq!(g[0].width, 64);
        assert_eq!(g[1].members, vec![3]);
        assert_eq!(g[1].width, 8);
    }

    #[test]
    fn plan_batches_single_session_uses_tight_width() {
        let g = plan_batches(&[3], 64);
        assert_eq!(g.len(), 1);
        assert_eq!(g[0].width, 4);
    }

    #[test]
    fn plan_batches_each_full_session_gets_own_group() {
        let g = plan_batches(&[64, 64], 64);
        assert_eq!(g.len(), 2);
        assert!(g.iter().all(|g| g.rows == 64 && g.width == 64));
    }

    #[test]
    #[should_panic(expected = "exceed max width")]
    fn plan_batches_rejects_oversized_sessions() {
        let _ = plan_batches(&[65], 64);
    }

    #[test]
    fn enveloped_batches_pin_a_static_padded_width() {
        // Three rounds of shrinking levels (sessions finish growing at
        // different depths) all pad to the same compiled width under a
        // 4 × 8 envelope — one graph serves the whole stream of calls.
        let envelope = 32;
        for rows in [&[8usize, 8, 8, 8][..], &[8, 8, 3][..], &[1][..]] {
            let g = plan_batches_enveloped(rows, 64, envelope);
            assert_eq!(g.len(), 1);
            assert_eq!(g[0].width, 32, "rows {rows:?} left the envelope width");
        }
        // Overflow past the envelope still widens to fit the rows.
        let g = plan_batches_enveloped(&[16, 16, 16], 64, envelope);
        assert_eq!(g[0].width, 64);
        // Envelope 0 degenerates to the tight plan.
        let g = plan_batches_enveloped(&[3], 64, 0);
        assert_eq!(g[0].width, 4);
        // The envelope clamps to the widest compiled graph.
        let g = plan_batches_enveloped(&[2], 64, 1000);
        assert_eq!(g[0].width, 64);
    }
}
