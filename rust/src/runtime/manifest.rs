//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime. The manifest pins model shapes, the weight-blob tensor
//! layout, the per-width HLO graph files, dataset prompt files and golden
//! vectors. Loading validates the pieces against each other so a stale or
//! partially-rebuilt artifacts directory fails fast instead of producing
//! garbage numerics.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// One tensor's location inside a model's weight blob.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    /// Tensor name (diagnostics).
    pub name: String,
    /// Dimensions.
    pub shape: Vec<usize>,
    /// Offset in f32 elements into the weight blob.
    pub offset: usize,
}

impl TensorSpec {
    /// Element count.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One model's architecture, capacities and artifact files.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    /// Transformer layer count.
    pub layers: usize,
    /// Residual width.
    pub d_model: usize,
    /// Attention heads.
    pub heads: usize,
    /// Per-head dimension.
    pub head_dim: usize,
    /// Feed-forward hidden width.
    pub ffn: usize,
    /// Vocabulary size.
    pub vocab: usize,
    /// KV-cache slots per instance (DESIGN.md §7).
    pub cache_capacity: usize,
    /// RoPE base.
    pub rope_theta: f64,
    /// Final-logits scale factor.
    pub logit_scale: f64,
    /// Total parameter count.
    pub param_count: usize,
    /// Weight-blob file name inside the bundle.
    pub weights_file: String,
    /// width (as string in JSON) -> HLO text file name.
    pub graphs: HashMap<String, String>,
    /// Compiled graph widths available for this model.
    pub widths: Vec<usize>,
    /// `"drafter"` or `"target"` (informational).
    pub role: String,
    /// Weight-blob layout.
    pub tensors: Vec<TensorSpec>,
}

impl ModelSpec {
    /// Flattened element count of the KV cache `[L, 2, C, H, Dh]`.
    pub fn cache_numel(&self) -> usize {
        self.layers * 2 * self.cache_capacity * self.heads * self.head_dim
    }

    /// Dimensions of the device KV-cache buffer.
    pub fn cache_dims(&self) -> [usize; 5] {
        [self.layers, 2, self.cache_capacity, self.heads, self.head_dim]
    }

    /// HLO file for a compiled width, if present.
    pub fn graph_file(&self, width: usize) -> Option<&str> {
        self.graphs.get(&width.to_string()).map(|s| s.as_str())
    }
}

/// A golden-output vector for numerics parity tests.
#[derive(Debug, Clone)]
pub struct GoldenSpec {
    /// Golden file name.
    pub file: String,
    /// Graph width the vector was produced at.
    pub width: usize,
}

/// The parsed artifact manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Manifest schema version.
    pub format_version: u32,
    /// Models by name.
    pub models: HashMap<String, ModelSpec>,
    /// Prompt-set files by dataset name.
    pub datasets: HashMap<String, String>,
    /// Golden vectors by model name.
    pub golden: HashMap<String, GoldenSpec>,
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
}

impl Manifest {
    /// Loads and cross-validates `manifest.json` from the bundle.
    pub fn load(artifacts_dir: &Path) -> crate::Result<Self> {
        let path = artifacts_dir.join("manifest.json");
        if !path.exists() {
            anyhow::bail!("cannot read {} — run `make artifacts` first", path.display());
        }
        let j = Json::parse_file(&path)?;
        let mut m = Self::from_json(&j)?;
        m.dir = artifacts_dir.to_path_buf();
        m.validate()?;
        Ok(m)
    }

    fn from_json(j: &Json) -> crate::Result<Self> {
        let mut models = HashMap::new();
        for (name, mj) in j.req("models")?.as_obj().ok_or_else(|| anyhow::anyhow!("models"))? {
            let mut graphs = HashMap::new();
            for (w, f) in mj.req("graphs")?.as_obj().ok_or_else(|| anyhow::anyhow!("graphs"))? {
                graphs.insert(
                    w.clone(),
                    f.as_str().ok_or_else(|| anyhow::anyhow!("graph file"))?.to_string(),
                );
            }
            let mut tensors = Vec::new();
            for t in mj.arr("tensors").unwrap_or(&[]) {
                tensors.push(TensorSpec {
                    name: t.str("name")?.to_string(),
                    shape: t.usize_vec("shape")?,
                    offset: t.usize("offset")?,
                });
            }
            models.insert(
                name.clone(),
                ModelSpec {
                    layers: mj.usize("layers")?,
                    d_model: mj.usize("d_model")?,
                    heads: mj.usize("heads")?,
                    head_dim: mj.usize("head_dim")?,
                    ffn: mj.usize("ffn")?,
                    vocab: mj.usize("vocab")?,
                    cache_capacity: mj.usize("cache_capacity")?,
                    rope_theta: mj.f64("rope_theta")?,
                    logit_scale: mj.f64("logit_scale")?,
                    param_count: mj.usize("param_count")?,
                    weights_file: mj.str("weights_file")?.to_string(),
                    graphs,
                    widths: mj.usize_vec("widths")?,
                    role: mj.str("role")?.to_string(),
                    tensors,
                },
            );
        }
        let mut datasets = HashMap::new();
        for (k, v) in j.req("datasets")?.as_obj().ok_or_else(|| anyhow::anyhow!("datasets"))? {
            datasets.insert(k.clone(), v.as_str().unwrap_or_default().to_string());
        }
        let mut golden = HashMap::new();
        for (k, v) in j.req("golden")?.as_obj().ok_or_else(|| anyhow::anyhow!("golden"))? {
            golden.insert(
                k.clone(),
                GoldenSpec { file: v.str("file")?.to_string(), width: v.usize("width")? },
            );
        }
        Ok(Manifest {
            format_version: j.usize("format_version")? as u32,
            models,
            datasets,
            golden,
            dir: PathBuf::new(),
        })
    }

    fn validate(&self) -> crate::Result<()> {
        anyhow::ensure!(self.format_version == 1, "unsupported manifest version");
        for (name, spec) in &self.models {
            // Tensor layout must tile the blob exactly.
            let mut expect = 0usize;
            for t in &spec.tensors {
                anyhow::ensure!(
                    t.offset == expect,
                    "{name}: tensor {} offset {} != expected {expect}",
                    t.name,
                    t.offset
                );
                expect += t.numel();
            }
            anyhow::ensure!(
                expect == spec.param_count,
                "{name}: tensors sum to {expect}, manifest says {}",
                spec.param_count
            );
            let blob = self.dir.join(&spec.weights_file);
            if let Ok(md) = std::fs::metadata(&blob) {
                anyhow::ensure!(
                    md.len() as usize == 4 * spec.param_count,
                    "{name}: weight blob {} has {} bytes, expected {}",
                    blob.display(),
                    md.len(),
                    4 * spec.param_count
                );
            }
            for w in &spec.widths {
                anyhow::ensure!(
                    spec.graph_file(*w).is_some(),
                    "{name}: missing graph entry for width {w}"
                );
            }
        }
        Ok(())
    }

    /// Spec for `name`, or an error naming the known models.
    pub fn model(&self, name: &str) -> crate::Result<&ModelSpec> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("model '{name}' not in manifest ({:?})", self.models.keys()))
    }

    /// Reads a model's weight blob as f32 tensors in manifest order.
    pub fn load_weights(&self, name: &str) -> crate::Result<Vec<(TensorSpec, Vec<f32>)>> {
        let spec = self.model(name)?;
        let path = self.dir.join(&spec.weights_file);
        let bytes = std::fs::read(&path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        anyhow::ensure!(bytes.len() == 4 * spec.param_count, "weight blob size mismatch");
        let mut out = Vec::with_capacity(spec.tensors.len());
        for t in &spec.tensors {
            let start = 4 * t.offset;
            let end = start + 4 * t.numel();
            let mut v = vec![0f32; t.numel()];
            for (i, chunk) in bytes[start..end].chunks_exact(4).enumerate() {
                v[i] = f32::from_le_bytes(chunk.try_into().unwrap());
            }
            out.push((t.clone(), v));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts() -> Option<Manifest> {
        let dir = Path::new("artifacts");
        (dir.join("manifest.json").exists() && dir.join("dft-xs.weights.bin").exists() && dir.join("tgt-lg.weights.bin").exists())
            .then(|| Manifest::load(dir).unwrap())
    }

    #[test]
    fn manifest_loads_and_validates() {
        let Some(m) = artifacts() else { return };
        assert!(m.models.contains_key("tgt-sm"));
        assert!(m.models.contains_key("dft-xs"));
        let spec = m.model("tgt-sm").unwrap();
        assert_eq!(spec.role, "target");
        assert_eq!(spec.widths, vec![1, 2, 4, 8, 16, 32, 64]);
        assert!(spec.graph_file(4).is_some());
        assert!(spec.graph_file(3).is_none());
    }

    #[test]
    fn weights_load_with_exact_layout() {
        let Some(m) = artifacts() else { return };
        let w = m.load_weights("dft-xs").unwrap();
        let spec = m.model("dft-xs").unwrap();
        assert_eq!(w.len(), spec.tensors.len());
        assert_eq!(w[0].0.name, "embed");
        let total: usize = w.iter().map(|(t, _)| t.numel()).sum();
        assert_eq!(total, spec.param_count);
        // Embeddings of a trained model are not all zero.
        assert!(w[0].1.iter().any(|&x| x != 0.0));
    }

    #[test]
    fn unknown_model_errors() {
        let Some(m) = artifacts() else { return };
        assert!(m.model("nope").is_err());
    }

    #[test]
    fn cache_dims_consistent() {
        let Some(m) = artifacts() else { return };
        let s = m.model("tgt-sm").unwrap();
        assert_eq!(s.cache_numel(), s.cache_dims().iter().product::<usize>());
    }
}
