//! Typed configuration system.
//!
//! Everything a deployment tunes lives here: which artifact bundle to load,
//! which drafter/verifier pair to run, the EGT envelope (max depth/width,
//! verification budget), which optimizations are enabled (the paper's
//! O1–O5 breakdown maps 1:1 onto [`EngineConfig`] flags), sampling, server
//! binding, and benchmark parameters. Configs are plain serde structs so
//! they load from JSON files and accept CLI overrides.

use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Static graph widths compiled by the AOT driver. Must match
/// `python/compile/configs.py::GRAPH_WIDTHS`.
pub const GRAPH_WIDTHS: [usize; 7] = [1, 2, 4, 8, 16, 32, 64];

/// Smallest compiled width that fits `n` tokens (padding goes to this).
pub fn width_for(n: usize) -> Option<usize> {
    GRAPH_WIDTHS.iter().copied().find(|&w| w >= n)
}

/// Which tree-construction algorithm an engine uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TreeStructure {
    /// Single chain of depth D (classic speculative decoding).
    Sequence,
    /// Static K-ary tree of top-K children per node (SpecInfer-style).
    KAry,
    /// Offline dataset-profiled static tree (Sequoia-style DP construction).
    Sequoia,
    /// Equal-Growth Tree: W leaves per step, attached anywhere (the paper).
    Egt,
}

/// What quantity draft selection maximizes — the paper's Fig. 14 ablation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    /// Average accepted length only (prior work's proxy).
    Aal,
    /// The latency-aware speedup objective, Eq. 3.
    Speedup,
}

/// Scheduling plan selection — §5 of the paper.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulePlan {
    /// Fully sequential stages (Fig. 9-(a) naive pipeline).
    Sequential,
    /// Ahead-of-time tail draft overlapped with acceptance.
    AotTail,
    /// AOT tail + ahead-of-time head draft overlapped with bookkeeping.
    AotTailHead,
    /// Pick the best plan from the profile-guided offline search.
    ProfileSearch,
}

/// Per-request generation parameters.
#[derive(Debug, Clone, Default)]
pub struct SamplingConfig {
    /// 0.0 = greedy. Tree acceptance switches to the stochastic
    /// (SpecInfer-style multi-branch residual) rule when > 0.
    pub temperature: f32,
    /// RNG seed (per-request reproducibility).
    pub seed: u64,
}

/// Cross-session batching (DESIGN.md §9–§10): when enabled, the engine
/// backs all concurrent sessions with **one** shared device cache per
/// model side and packs the ready sessions' verification trees into one
/// width-padded device call per scheduling round (block-diagonal mask
/// keeps sessions invisible to one another). The shared cache is carved
/// either into a paged block pool (`paged`, the default — slots flow to
/// whoever needs them, DESIGN.md §10) or into equal fixed per-session
/// regions (the `--equal-partition` fallback, DESIGN.md §9).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BatchConfig {
    /// Share device caches and batch verification across sessions.
    pub enabled: bool,
    /// Sessions the shared cache is partitioned for in equal-partition
    /// mode (each gets `(capacity - 1) / max_sessions` slots); in paged
    /// mode admission is token-level and this only sizes envelope
    /// amortization estimates.
    pub max_sessions: usize,
    /// Lease the shared cache block-by-block on demand instead of in
    /// equal fixed regions.
    pub paged: bool,
    /// Pack the draft stages (head draft + every equal-growth tree-draft
    /// level) across sessions into one width-padded drafter call per
    /// round, in addition to the batched verify (stage-aligned batched
    /// drafting, DESIGN.md §11). `false` (`--no-batch-draft`) restores
    /// the verify-only batching of DESIGN.md §9, where each session's
    /// draft calls issue serially.
    pub batch_draft: bool,
    /// Slots per block in paged mode (`--block-size`). Validated by
    /// [`crate::kvcache::BlockPool::new`]: must be ≥ 2 and fit the cache.
    pub block_size: usize,
    /// Optional cap on the number of pool blocks (`--cache-blocks`);
    /// `None` uses everything the capacity can host.
    pub cache_blocks: Option<usize>,
    /// Cross-request radix prefix cache over the paged pool (DESIGN.md
    /// §12, the default; `--no-prefix-cache` disables): completed
    /// sessions' fully-committed prompt blocks stay cached and later
    /// requests sharing the prefix attach them read-only, prefilling
    /// only the uncached tail. Only meaningful when `paged`.
    pub prefix_cache: bool,
    /// CPU worker threads for the per-session CPU stages of a batched
    /// round (`--cpu-threads`): `1` runs them serially (the default),
    /// `0` auto-sizes to the machine's available parallelism, `N > 1`
    /// fans the pruning stage across sessions on `N` scoped threads
    /// (DESIGN.md §13).
    pub cpu_threads: usize,
    /// Prefill chunk size in tokens (`--prefill-chunk`): when > 0, cold
    /// prompts prefill at most this many tokens per side per batched
    /// round instead of in one shot, so a long prompt cannot stall the
    /// warm sessions packed into the same wave (DESIGN.md §14). `0`
    /// (the default) keeps one-shot prefill.
    pub prefill_chunk: usize,
    /// Global round-level speculation allocator (DESIGN.md §15, the
    /// default; `--no-global-alloc` disables): each batched round
    /// distributes one round-wide verification-token budget across the
    /// packed sessions by marginal expected-accepted-tokens — deep trees
    /// for high-acceptance sessions, shallow or draft-skipped rounds for
    /// low-acceptance ones — instead of the uniform per-session clamp.
    pub global_alloc: bool,
}

impl Default for BatchConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            max_sessions: 4,
            paged: true,
            batch_draft: true,
            block_size: 16,
            cache_blocks: None,
            prefix_cache: true,
            cpu_threads: 1,
            prefill_chunk: 0,
            global_alloc: true,
        }
    }
}

/// The Yggdrasil engine configuration. Defaults reproduce the full system
/// (all five optimizations on); the Fig. 12 breakdown toggles these.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Verifier model name in the artifact manifest.
    pub target: String,
    /// Drafter model name.
    pub drafter: String,
    /// Tree construction algorithm (O1).
    pub tree: TreeStructure,
    /// Draft-selection objective (Eq. 3 vs AAL; Fig. 14).
    pub objective: Objective,
    /// Enable verification-width pruning (O3). When off, the whole grown
    /// tree (padded to a graph width) is verified.
    pub prune: bool,
    /// Stage-scheduling plan (O4).
    pub schedule: SchedulePlan,
    /// Use the trained depth predictor (O5). When off, `max_depth` is used.
    pub use_depth_predictor: bool,
    /// Execute with resident weights + cached executables (true, the
    /// compiled-runtime path) or restage weights per call (false — the
    /// eager-runtime analog used by the SpecInfer baseline; Fig. 4/10).
    pub compiled: bool,
    /// EGT envelope.
    pub max_depth: usize,
    /// Maximum equal-growth width per draft step.
    pub max_width: usize,
    /// Verification-width budget (tokens per verifier call).
    pub max_verify: usize,
    /// Candidate children considered per expanded node.
    pub branch_candidates: usize,
    /// Per-request sampling parameters.
    pub sampling: SamplingConfig,
    /// Hard cap on generated tokens per request.
    pub max_new_tokens: usize,
    /// Cross-session batched verification (DESIGN.md §9).
    pub batch: BatchConfig,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            target: "tgt-sm".into(),
            drafter: "dft-xs".into(),
            tree: TreeStructure::Egt,
            objective: Objective::Speedup,
            prune: true,
            schedule: SchedulePlan::ProfileSearch,
            use_depth_predictor: true,
            compiled: true,
            max_depth: 8,
            max_width: 8,
            max_verify: 64,
            branch_candidates: 8,
            sampling: SamplingConfig::default(),
            max_new_tokens: 128,
            batch: BatchConfig::default(),
        }
    }
}

impl EngineConfig {
    /// Baseline preset: classic sequence speculative decoding, depth `d`
    /// (eager runtime, like the original Leviathan et al. setting).
    pub fn preset_seqspec(d: usize) -> Self {
        Self {
            tree: TreeStructure::Sequence,
            objective: Objective::Aal,
            prune: false,
            schedule: SchedulePlan::Sequential,
            use_depth_predictor: false,
            compiled: false,
            max_depth: d,
            max_width: 1,
            max_verify: d + 1,
            ..Self::default()
        }
    }

    /// Baseline preset: vLLM-Spec — sequence speculation on the compiled
    /// static runtime.
    pub fn preset_vllmspec(d: usize) -> Self {
        Self { compiled: true, ..Self::preset_seqspec(d) }
    }

    /// Baseline preset: SpecInfer-style static K-ary tree on the eager
    /// runtime (its FlexFlow serving stack predates graph compilation).
    pub fn preset_specinfer(k: usize, depth: usize, verify: usize) -> Self {
        Self {
            tree: TreeStructure::KAry,
            objective: Objective::Aal,
            prune: false,
            schedule: SchedulePlan::Sequential,
            use_depth_predictor: false,
            compiled: false,
            max_depth: depth,
            max_width: k,
            max_verify: verify,
            ..Self::default()
        }
    }

    /// Baseline preset: Sequoia-style dataset-profiled static tree.
    pub fn preset_sequoia(verify: usize) -> Self {
        Self {
            tree: TreeStructure::Sequoia,
            objective: Objective::Aal,
            prune: false,
            schedule: SchedulePlan::Sequential,
            use_depth_predictor: false,
            compiled: true,
            max_depth: 8,
            max_width: 8,
            max_verify: verify,
            ..Self::default()
        }
    }
}

/// Where artifacts live and which profile file to use.
#[derive(Debug, Clone)]
pub struct RuntimeConfig {
    /// AOT artifact bundle directory.
    pub artifacts_dir: PathBuf,
    /// Latency profile (written by `yggdrasil profile`); optional — the
    /// runtime falls back to profiling at startup when absent.
    pub profile_file: Option<PathBuf>,
    /// Depth-predictor weights (written by `yggdrasil train-predictor`).
    pub predictor_file: Option<PathBuf>,
}

impl Default for RuntimeConfig {
    fn default() -> Self {
        Self {
            artifacts_dir: PathBuf::from("artifacts"),
            profile_file: Some(PathBuf::from("artifacts/profile.json")),
            predictor_file: Some(PathBuf::from("artifacts/predictor.json")),
        }
    }
}

/// Server binding / limits.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Bind address, e.g. `127.0.0.1:7777`.
    pub addr: String,
    /// Bounded request-queue length.
    pub max_queue: usize,
    /// Concurrent decode sessions the continuous-serving scheduler
    /// interleaves (admission beyond this queues; see `server::sessions`).
    pub max_sessions: usize,
    /// Stream tokens as they are accepted (vs. one final response).
    pub stream: bool,
    /// Drive live sessions through the engine's batched round
    /// (`StepEngine::step_batch`) instead of serial round-robin stepping.
    pub batched: bool,
    /// Data-parallel engine workers (`--workers`, DESIGN.md §16): each
    /// owns its own cache pool, prefix trie, and scheduler thread.
    pub workers: usize,
    /// Request-placement policy across the worker fleet (`--routing`).
    pub routing: crate::server::RoutingPolicy,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7777".into(),
            max_queue: 256,
            max_sessions: 4,
            stream: true,
            batched: true,
            workers: 1,
            routing: crate::server::RoutingPolicy::Affinity,
        }
    }
}

/// Top-level config file (`--config foo.json`).
#[derive(Debug, Clone, Default)]
pub struct AppConfig {
    /// Artifact/profile locations.
    pub runtime: RuntimeConfig,
    /// Engine configuration.
    pub engine: EngineConfig,
    /// Server binding and limits.
    pub server: ServerConfig,
}

// ---------------------------------------------------------------------------
// JSON persistence (in-tree util::json; every field has a default so config
// files may be partial).
// ---------------------------------------------------------------------------

impl TreeStructure {
    /// Stable config-file string form.
    pub fn as_str(&self) -> &'static str {
        match self {
            TreeStructure::Sequence => "sequence",
            TreeStructure::KAry => "k_ary",
            TreeStructure::Sequoia => "sequoia",
            TreeStructure::Egt => "egt",
        }
    }

    /// Parses the config-file string form.
    pub fn from_str(s: &str) -> crate::Result<Self> {
        Ok(match s {
            "sequence" => TreeStructure::Sequence,
            "k_ary" => TreeStructure::KAry,
            "sequoia" => TreeStructure::Sequoia,
            "egt" => TreeStructure::Egt,
            _ => anyhow::bail!("unknown tree structure '{s}'"),
        })
    }
}

impl Objective {
    /// Stable config-file string form.
    pub fn as_str(&self) -> &'static str {
        match self {
            Objective::Aal => "aal",
            Objective::Speedup => "speedup",
        }
    }

    /// Parses the config-file string form.
    pub fn from_str(s: &str) -> crate::Result<Self> {
        Ok(match s {
            "aal" => Objective::Aal,
            "speedup" => Objective::Speedup,
            _ => anyhow::bail!("unknown objective '{s}'"),
        })
    }
}

impl SchedulePlan {
    /// Stable config-file string form.
    pub fn as_str(&self) -> &'static str {
        match self {
            SchedulePlan::Sequential => "sequential",
            SchedulePlan::AotTail => "aot_tail",
            SchedulePlan::AotTailHead => "aot_tail_head",
            SchedulePlan::ProfileSearch => "profile_search",
        }
    }

    /// Parses the config-file string form.
    pub fn from_str(s: &str) -> crate::Result<Self> {
        Ok(match s {
            "sequential" => SchedulePlan::Sequential,
            "aot_tail" => SchedulePlan::AotTail,
            "aot_tail_head" => SchedulePlan::AotTailHead,
            "profile_search" => SchedulePlan::ProfileSearch,
            _ => anyhow::bail!("unknown schedule plan '{s}'"),
        })
    }
}

impl EngineConfig {
    /// Serializes to the config-file JSON form.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("target", Json::Str(self.target.clone())),
            ("drafter", Json::Str(self.drafter.clone())),
            ("tree", Json::Str(self.tree.as_str().into())),
            ("objective", Json::Str(self.objective.as_str().into())),
            ("prune", Json::Bool(self.prune)),
            ("compiled", Json::Bool(self.compiled)),
            ("schedule", Json::Str(self.schedule.as_str().into())),
            ("use_depth_predictor", Json::Bool(self.use_depth_predictor)),
            ("max_depth", Json::Num(self.max_depth as f64)),
            ("max_width", Json::Num(self.max_width as f64)),
            ("max_verify", Json::Num(self.max_verify as f64)),
            ("branch_candidates", Json::Num(self.branch_candidates as f64)),
            ("temperature", Json::Num(self.sampling.temperature as f64)),
            ("seed", Json::Num(self.sampling.seed as f64)),
            ("max_new_tokens", Json::Num(self.max_new_tokens as f64)),
            ("batch_enabled", Json::Bool(self.batch.enabled)),
            ("batch_max_sessions", Json::Num(self.batch.max_sessions as f64)),
            ("batch_paged", Json::Bool(self.batch.paged)),
            ("batch_draft", Json::Bool(self.batch.batch_draft)),
            ("batch_block_size", Json::Num(self.batch.block_size as f64)),
            (
                "batch_cache_blocks",
                match self.batch.cache_blocks {
                    Some(b) => Json::Num(b as f64),
                    None => Json::Null,
                },
            ),
            ("batch_prefix_cache", Json::Bool(self.batch.prefix_cache)),
            ("batch_cpu_threads", Json::Num(self.batch.cpu_threads as f64)),
            ("batch_prefill_chunk", Json::Num(self.batch.prefill_chunk as f64)),
            ("batch_global_alloc", Json::Bool(self.batch.global_alloc)),
        ])
    }

    /// Deserializes, filling absent fields from the defaults.
    pub fn from_json(j: &Json) -> crate::Result<Self> {
        let d = Self::default();
        let get_s = |k: &str, dv: &str| j.get(k).and_then(|v| v.as_str()).unwrap_or(dv).to_string();
        let get_u = |k: &str, dv: usize| j.get(k).and_then(|v| v.as_usize()).unwrap_or(dv);
        let get_b = |k: &str, dv: bool| j.get(k).and_then(|v| v.as_bool()).unwrap_or(dv);
        Ok(Self {
            target: get_s("target", &d.target),
            drafter: get_s("drafter", &d.drafter),
            tree: TreeStructure::from_str(&get_s("tree", d.tree.as_str()))?,
            objective: Objective::from_str(&get_s("objective", d.objective.as_str()))?,
            prune: get_b("prune", d.prune),
            compiled: get_b("compiled", d.compiled),
            schedule: SchedulePlan::from_str(&get_s("schedule", d.schedule.as_str()))?,
            use_depth_predictor: get_b("use_depth_predictor", d.use_depth_predictor),
            max_depth: get_u("max_depth", d.max_depth),
            max_width: get_u("max_width", d.max_width),
            max_verify: get_u("max_verify", d.max_verify),
            branch_candidates: get_u("branch_candidates", d.branch_candidates),
            sampling: SamplingConfig {
                temperature: j.get("temperature").and_then(|v| v.as_f64()).unwrap_or(0.0) as f32,
                seed: j.get("seed").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64,
            },
            max_new_tokens: get_u("max_new_tokens", d.max_new_tokens),
            batch: BatchConfig {
                enabled: get_b("batch_enabled", d.batch.enabled),
                max_sessions: get_u("batch_max_sessions", d.batch.max_sessions).max(1),
                paged: get_b("batch_paged", d.batch.paged),
                batch_draft: get_b("batch_draft", d.batch.batch_draft),
                block_size: get_u("batch_block_size", d.batch.block_size),
                cache_blocks: j.get("batch_cache_blocks").and_then(|v| v.as_usize()),
                prefix_cache: get_b("batch_prefix_cache", d.batch.prefix_cache),
                cpu_threads: get_u("batch_cpu_threads", d.batch.cpu_threads),
                prefill_chunk: get_u("batch_prefill_chunk", d.batch.prefill_chunk),
                global_alloc: get_b("batch_global_alloc", d.batch.global_alloc),
            },
        })
    }
}

impl AppConfig {
    /// Serializes to the config-file JSON form.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "runtime",
                Json::obj(vec![
                    (
                        "artifacts_dir",
                        Json::Str(self.runtime.artifacts_dir.display().to_string()),
                    ),
                    (
                        "profile_file",
                        match &self.runtime.profile_file {
                            Some(p) => Json::Str(p.display().to_string()),
                            None => Json::Null,
                        },
                    ),
                    (
                        "predictor_file",
                        match &self.runtime.predictor_file {
                            Some(p) => Json::Str(p.display().to_string()),
                            None => Json::Null,
                        },
                    ),
                ]),
            ),
            ("engine", self.engine.to_json()),
            (
                "server",
                Json::obj(vec![
                    ("addr", Json::Str(self.server.addr.clone())),
                    ("max_queue", Json::Num(self.server.max_queue as f64)),
                    ("max_sessions", Json::Num(self.server.max_sessions as f64)),
                    ("stream", Json::Bool(self.server.stream)),
                    ("batched", Json::Bool(self.server.batched)),
                    ("workers", Json::Num(self.server.workers as f64)),
                    ("routing", Json::Str(self.server.routing.as_str().into())),
                ]),
            ),
        ])
    }

    /// Deserializes, filling absent fields from the defaults.
    pub fn from_json(j: &Json) -> crate::Result<Self> {
        let mut cfg = AppConfig::default();
        if let Some(r) = j.get("runtime") {
            if let Some(d) = r.get("artifacts_dir").and_then(|v| v.as_str()) {
                cfg.runtime.artifacts_dir = PathBuf::from(d);
            }
            if let Some(p) = r.get("profile_file") {
                cfg.runtime.profile_file = p.as_str().map(PathBuf::from);
            }
            if let Some(p) = r.get("predictor_file") {
                cfg.runtime.predictor_file = p.as_str().map(PathBuf::from);
            }
        }
        if let Some(e) = j.get("engine") {
            cfg.engine = EngineConfig::from_json(e)?;
        }
        if let Some(s) = j.get("server") {
            if let Some(a) = s.get("addr").and_then(|v| v.as_str()) {
                cfg.server.addr = a.to_string();
            }
            if let Some(q) = s.get("max_queue").and_then(|v| v.as_usize()) {
                cfg.server.max_queue = q;
            }
            if let Some(m) = s.get("max_sessions").and_then(|v| v.as_usize()) {
                cfg.server.max_sessions = m.max(1);
            }
            if let Some(b) = s.get("stream").and_then(|v| v.as_bool()) {
                cfg.server.stream = b;
            }
            if let Some(b) = s.get("batched").and_then(|v| v.as_bool()) {
                cfg.server.batched = b;
            }
            if let Some(w) = s.get("workers").and_then(|v| v.as_usize()) {
                cfg.server.workers = w.max(1);
            }
            if let Some(r) = s.get("routing").and_then(|v| v.as_str()) {
                cfg.server.routing = crate::server::RoutingPolicy::from_str(r)?;
            }
        }
        Ok(cfg)
    }

    /// Loads a (possibly partial) JSON config file.
    pub fn load(path: &Path) -> crate::Result<Self> {
        Self::from_json(&Json::parse_file(path)?)
    }

    /// Writes the full config as JSON.
    pub fn save(&self, path: &Path) -> crate::Result<()> {
        self.to_json().save(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn width_for_picks_smallest_fit() {
        assert_eq!(width_for(1), Some(1));
        assert_eq!(width_for(3), Some(4));
        assert_eq!(width_for(4), Some(4));
        assert_eq!(width_for(33), Some(64));
        assert_eq!(width_for(64), Some(64));
        assert_eq!(width_for(65), None);
    }

    #[test]
    fn config_roundtrip_json() {
        let mut cfg = AppConfig::default();
        cfg.engine.tree = TreeStructure::Sequoia;
        cfg.engine.max_depth = 11;
        cfg.engine.sampling.temperature = 0.75;
        cfg.server.stream = false;
        cfg.server.max_sessions = 9;
        cfg.server.batched = false;
        cfg.server.workers = 3;
        cfg.server.routing = crate::server::RoutingPolicy::LeastLoaded;
        cfg.engine.batch = BatchConfig {
            enabled: true,
            max_sessions: 6,
            paged: false,
            batch_draft: false,
            block_size: 8,
            cache_blocks: Some(12),
            prefix_cache: false,
            cpu_threads: 3,
            prefill_chunk: 24,
            global_alloc: false,
        };
        let back = AppConfig::from_json(&Json::parse(&cfg.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back.engine.target, cfg.engine.target);
        assert_eq!(back.engine.tree, TreeStructure::Sequoia);
        assert_eq!(back.engine.max_depth, 11);
        assert!((back.engine.sampling.temperature - 0.75).abs() < 1e-6);
        assert!(!back.server.stream);
        assert_eq!(back.server.max_sessions, 9);
        assert!(!back.server.batched);
        assert_eq!(back.server.workers, 3);
        assert_eq!(back.server.routing, crate::server::RoutingPolicy::LeastLoaded);
        assert_eq!(back.engine.batch, cfg.engine.batch);
    }

    #[test]
    fn batch_defaults_are_paged_and_absent_cache_blocks_stay_none() {
        let d = BatchConfig::default();
        assert!(d.paged, "paged block leasing is the default shared-cache layout");
        assert!(d.batch_draft, "stage-aligned batched drafting is the default");
        assert!(d.prefix_cache, "cross-request prefix caching is the default");
        assert!(d.cache_blocks.is_none());
        assert_eq!(d.cpu_threads, 1, "CPU stages run serially unless asked");
        let j = Json::parse(r#"{"engine": {"batch_enabled": true}}"#).unwrap();
        let cfg = AppConfig::from_json(&j).unwrap();
        assert!(cfg.engine.batch.enabled && cfg.engine.batch.paged);
        assert!(cfg.engine.batch.prefix_cache, "absent key keeps the prefix-cache default");
        assert_eq!(cfg.engine.batch.block_size, d.block_size);
        assert!(cfg.engine.batch.cache_blocks.is_none());
        assert_eq!(cfg.engine.batch.cpu_threads, 1, "absent key keeps the serial default");
        assert_eq!(d.prefill_chunk, 0, "one-shot prefill is the default");
        assert_eq!(cfg.engine.batch.prefill_chunk, 0, "absent key keeps one-shot prefill");
        assert!(d.global_alloc, "the global round allocator is the default");
        assert!(cfg.engine.batch.global_alloc, "absent key keeps the allocator on");
    }

    #[test]
    fn partial_config_uses_defaults() {
        let j = Json::parse(r#"{"engine": {"max_depth": 3}}"#).unwrap();
        let cfg = AppConfig::from_json(&j).unwrap();
        assert_eq!(cfg.engine.max_depth, 3);
        assert_eq!(cfg.engine.tree, TreeStructure::Egt);
        assert_eq!(cfg.server.addr, "127.0.0.1:7777");
    }

    #[test]
    fn enum_string_roundtrip() {
        for t in [TreeStructure::Sequence, TreeStructure::KAry, TreeStructure::Sequoia, TreeStructure::Egt] {
            assert_eq!(TreeStructure::from_str(t.as_str()).unwrap(), t);
        }
        for p in [SchedulePlan::Sequential, SchedulePlan::AotTail, SchedulePlan::AotTailHead, SchedulePlan::ProfileSearch] {
            assert_eq!(SchedulePlan::from_str(p.as_str()).unwrap(), p);
        }
        assert!(TreeStructure::from_str("bogus").is_err());
    }

    #[test]
    fn presets_have_expected_shapes() {
        let s = EngineConfig::preset_seqspec(5);
        assert_eq!(s.tree, TreeStructure::Sequence);
        assert_eq!(s.max_width, 1);
        assert_eq!(s.max_verify, 6);
        let k = EngineConfig::preset_specinfer(4, 4, 32);
        assert_eq!(k.tree, TreeStructure::KAry);
        assert_eq!(k.max_width, 4);
    }

    #[test]
    fn default_engine_is_full_system() {
        let e = EngineConfig::default();
        assert!(e.prune && e.use_depth_predictor);
        assert_eq!(e.objective, Objective::Speedup);
        assert_eq!(e.schedule, SchedulePlan::ProfileSearch);
    }
}
