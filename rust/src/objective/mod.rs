//! The latency-aware optimization objective — §4.1 of the paper.
//!
//! Prior systems maximise AAL, implicitly assuming verification cost is
//! independent of the number of verified tokens (Eq. 1). Yggdrasil instead
//! maximises the *measured-latency* speedup of Eq. 3:
//!
//! ```text
//!            AAL(W_draft, D_draft, W_verify) · T_verifier(1)
//! Speedup = ─────────────────────────────────────────────────
//!             Σ_{D_draft} T_drafter(W_draft) + T_verifier(W_verify)
//! ```
//!
//! where `T_model(W)` are hardware-profiled latency curves over the static
//! graph widths. This module holds:
//!
//! * [`LatencyCurve`] — monotone piecewise-linear interpolation over the
//!   profiled `(width, seconds)` points (queried at graph widths only, but
//!   interpolation keeps the objective smooth for the simulator sweeps);
//! * [`LatencyModel`] — drafter + verifier curves + the measured CPU
//!   bookkeeping overhead per iteration, with the Eq. 2 / Eq. 3 evaluators;
//! * [`AcceptanceStats`] — online EWMA estimates of the per-width coverage
//!   probability `q_W` (how often the verifier's next token is inside a
//!   width-W growth step) from which the expected AAL of a candidate
//!   `(D, W)` envelope is predicted before drafting.


use crate::util::json::Json;

/// Monotone piecewise-linear latency curve `T(width)`.
#[derive(Debug, Clone)]
pub struct LatencyCurve {
    /// Strictly increasing widths (the compiled graph widths).
    pub widths: Vec<f64>,
    /// Seconds per call at each width.
    pub seconds: Vec<f64>,
}

impl LatencyCurve {
    /// Builds a curve from `(width, seconds)` points (sorted internally).
    pub fn new(points: &[(usize, f64)]) -> Self {
        let mut pts: Vec<(usize, f64)> = points.to_vec();
        pts.sort_by_key(|p| p.0);
        assert!(!pts.is_empty(), "latency curve needs at least one point");
        Self {
            widths: pts.iter().map(|p| p.0 as f64).collect(),
            seconds: pts.iter().map(|p| p.1).collect(),
        }
    }

    /// Interpolated latency at `w` (clamped extrapolation at the ends).
    pub fn at(&self, w: f64) -> f64 {
        let n = self.widths.len();
        if w <= self.widths[0] {
            return self.seconds[0];
        }
        if w >= self.widths[n - 1] {
            // Extrapolate with the last segment's slope (saturated region
            // grows roughly linearly in compute-bound width).
            if n >= 2 {
                let dx = self.widths[n - 1] - self.widths[n - 2];
                let dy = self.seconds[n - 1] - self.seconds[n - 2];
                return self.seconds[n - 1] + (w - self.widths[n - 1]) * dy / dx.max(1e-12);
            }
            return self.seconds[n - 1];
        }
        let i = self.widths.partition_point(|&x| x <= w) - 1;
        let t = (w - self.widths[i]) / (self.widths[i + 1] - self.widths[i]);
        self.seconds[i] * (1.0 - t) + self.seconds[i + 1] * t
    }
}

/// Profiled latency model for one (drafter, verifier) deployment.
#[derive(Debug, Clone)]
pub struct LatencyModel {
    /// Drafter latency curve.
    pub drafter: LatencyCurve,
    /// Verifier latency curve.
    pub verifier: LatencyCurve,
    /// Measured CPU bookkeeping seconds per decoding iteration (tree
    /// building, masks, acceptance walk) under the *sequential* plan.
    pub cpu_overhead: f64,
}

impl LatencyModel {
    /// Drafter seconds at width `w`.
    pub fn t_draft(&self, w: usize) -> f64 {
        self.drafter.at(w as f64)
    }

    /// Verifier seconds at width `w`.
    pub fn t_verify(&self, w: usize) -> f64 {
        self.verifier.at(w as f64)
    }

    /// Eq. 2 (vanilla sequence speculation): speedup of drafting
    /// `num_draft` tokens sequentially then verifying `num_draft + 1`.
    pub fn speedup_sequence(&self, aal: f64, num_draft: usize) -> f64 {
        let t_spec = num_draft as f64 * self.t_draft(1)
            + self.t_verify(num_draft + 1)
            + self.cpu_overhead;
        aal * self.t_verify(1) / t_spec
    }

    /// Eq. 3 (tree speculation): `draft_widths` holds the width of each of
    /// the `D_draft` drafter invocations (EGT uses a constant width; the
    /// static baselines use their per-level node counts).
    pub fn speedup_tree(&self, aal: f64, draft_widths: &[usize], w_verify: usize) -> f64 {
        let t_draft: f64 = draft_widths.iter().map(|&w| self.t_draft(w)).sum();
        let t_spec = t_draft + self.t_verify(w_verify) + self.cpu_overhead;
        aal * self.t_verify(1) / t_spec
    }

    /// Wall-clock seconds of one speculative iteration under this model.
    pub fn iteration_seconds(&self, draft_widths: &[usize], w_verify: usize) -> f64 {
        draft_widths.iter().map(|&w| self.t_draft(w)).sum::<f64>()
            + self.t_verify(w_verify)
            + self.cpu_overhead
    }

    /// Per-token latency (TPOT) implied by an AAL under this model.
    pub fn tpot(&self, aal: f64, draft_widths: &[usize], w_verify: usize) -> f64 {
        self.iteration_seconds(draft_widths, w_verify) / aal.max(1e-9)
    }
}

impl LatencyCurve {
    /// JSON form (profile files).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("widths", Json::from_f64s(&self.widths)),
            ("seconds", Json::from_f64s(&self.seconds)),
        ])
    }

    /// Parses the JSON form.
    pub fn from_json(j: &Json) -> crate::Result<Self> {
        let c = Self { widths: j.f64_vec("widths")?, seconds: j.f64_vec("seconds")? };
        anyhow::ensure!(
            !c.widths.is_empty() && c.widths.len() == c.seconds.len(),
            "malformed latency curve"
        );
        Ok(c)
    }
}

impl LatencyModel {
    /// JSON form (profile files).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("drafter", self.drafter.to_json()),
            ("verifier", self.verifier.to_json()),
            ("cpu_overhead", Json::Num(self.cpu_overhead)),
        ])
    }

    /// Parses the JSON form.
    pub fn from_json(j: &Json) -> crate::Result<Self> {
        Ok(Self {
            drafter: LatencyCurve::from_json(j.req("drafter")?)?,
            verifier: LatencyCurve::from_json(j.req("verifier")?)?,
            cpu_overhead: j.f64("cpu_overhead")?,
        })
    }

    /// Writes the profile JSON.
    pub fn save(&self, path: &std::path::Path) -> crate::Result<()> {
        self.to_json().save(path)
    }

    /// Loads a profile JSON.
    pub fn load(path: &std::path::Path) -> crate::Result<Self> {
        Self::from_json(&Json::parse_file(path)?)
    }
}

/// Online acceptance statistics: `q[w-bucket]` estimates the probability
/// that one equal-growth step of width `w` *covers* the verifier's true
/// next token along the accepted path. Expected AAL of a `(D, W)` envelope
/// follows the truncated geometric model `1 + Σ_{d=1..D} q_W^d` used by the
/// draft-width selector.
#[derive(Debug, Clone)]
pub struct AcceptanceStats {
    /// Indexed by graph-width index (see [`crate::config::GRAPH_WIDTHS`]).
    pub q_by_width: Vec<f64>,
    /// EWMA smoothing factor for online updates.
    pub alpha: f64,
    /// Acceptance-by-rank vector (for Sequoia construction & Fig. 11).
    pub accept_by_rank: Vec<f64>,
    /// Raw hit counts per rank (diagnostics).
    pub rank_counts: Vec<u64>,
}

impl Default for AcceptanceStats {
    fn default() -> Self {
        // Neutral prior (coverage rises with width); the EWMA converges to
        // the measured values within a few dozen decoding steps.
        let widths = crate::config::GRAPH_WIDTHS;
        Self {
            q_by_width: widths.iter().map(|&w| 1.0 - 0.35 / (w as f64).sqrt()).collect(),
            alpha: 0.05,
            accept_by_rank: vec![0.6, 0.2, 0.1, 0.05, 0.03, 0.02, 0.01, 0.01],
            rank_counts: vec![0; 8],
        }
    }
}

impl AcceptanceStats {
    fn widx(w: usize) -> usize {
        crate::config::GRAPH_WIDTHS
            .iter()
            .position(|&x| x >= w)
            .unwrap_or(crate::config::GRAPH_WIDTHS.len() - 1)
    }

    /// Records whether a width-`w` growth step covered the true token.
    pub fn record_step(&mut self, w: usize, covered: bool) {
        let i = Self::widx(w);
        let x = if covered { 1.0 } else { 0.0 };
        self.q_by_width[i] = (1.0 - self.alpha) * self.q_by_width[i] + self.alpha * x;
    }

    /// Records that the verifier's true token was the drafter's rank-`r`
    /// candidate (or `None` if outside the candidate set).
    pub fn record_rank(&mut self, rank: Option<usize>) {
        let n = self.accept_by_rank.len();
        for r in 0..n {
            let hit = matches!(rank, Some(rr) if rr == r);
            let x = if hit { 1.0 } else { 0.0 };
            self.accept_by_rank[r] = (1.0 - self.alpha) * self.accept_by_rank[r] + self.alpha * x;
            self.rank_counts[r] += hit as u64;
        }
    }

    /// Clamped coverage estimate for width `w`.
    pub fn q(&self, w: usize) -> f64 {
        self.q_by_width[Self::widx(w)].clamp(0.01, 0.999)
    }

    /// Expected AAL of a depth-`d`, width-`w` equal-growth envelope:
    /// `1 + q + q² + … + q^d` (the +1 is the bonus token).
    pub fn expected_aal(&self, d: usize, w: usize) -> f64 {
        let q = self.q(w);
        let mut total = 1.0;
        let mut p = 1.0;
        for _ in 0..d {
            p *= q;
            total += p;
        }
        total
    }
}

/// Online per-*session* acceptance estimator (DESIGN.md §15): one EWMA
/// over the session's own `complete_verify` accept counts, seeded from
/// the engine-wide [`AcceptanceStats`] prior so a fresh session inherits
/// the fleet's current estimate instead of a cold guess. The global
/// round allocator reads `q()` to decide how many verification rows this
/// session's next tree is worth.
///
/// The observable per round is `(accepted levels) / (offered levels)` —
/// the maximum-likelihood per-level acceptance of the truncated
/// geometric chain the Eq. 3 objective prices. A faster EWMA than the
/// shared stats (`alpha = 0.15` vs `0.05`) is deliberate: the estimator
/// must separate an easy prompt from a hard one within a few rounds of
/// one request's lifetime, not over a whole serving epoch.
#[derive(Debug, Clone)]
pub struct AcceptanceEstimator {
    q: f64,
    /// EWMA smoothing factor for per-round updates.
    alpha: f64,
    rounds: u64,
}

impl AcceptanceEstimator {
    /// A new estimator starting from the prior `q0` (typically the
    /// shared [`AcceptanceStats::q`] at the session's draft width).
    pub fn seeded(q0: f64) -> Self {
        Self { q: q0.clamp(0.01, 0.999), alpha: 0.15, rounds: 0 }
    }

    /// Folds in one round: the acceptance walk descended `accepted` of
    /// the `offered` drafted levels. Draft-skipped rounds (`offered ==
    /// 0`) carry no signal and leave the estimate untouched.
    pub fn record_round(&mut self, accepted: usize, offered: usize) {
        if offered == 0 {
            return;
        }
        let obs = (accepted.min(offered) as f64 / offered as f64).clamp(0.0, 1.0);
        self.q = ((1.0 - self.alpha) * self.q + self.alpha * obs).clamp(0.01, 0.999);
        self.rounds += 1;
    }

    /// A draft-skipped round (floor allocator grant) yields no
    /// acceptance signal; drift the estimate up slightly instead, so a
    /// low-acceptance session periodically re-earns a probe tree rather
    /// than starving forever on a stale estimate.
    pub fn drift_up(&mut self) {
        self.q = (self.q + 0.01).clamp(0.01, 0.999);
    }

    /// The current per-level acceptance estimate.
    pub fn q(&self) -> f64 {
        self.q
    }

    /// How many informative rounds have been folded in.
    pub fn rounds(&self) -> u64 {
        self.rounds
    }
}

/// Jointly selects draft depth and width under the configured objective —
/// used when no depth predictor is available (the predictor, when present,
/// supplies `depth` and only the width is selected). Under the AAL
/// objective this degenerates to the maximal envelope (prior work's
/// behaviour); under Eq. 3 it finds the latency-optimal ⟨D, W⟩.
pub fn select_depth_width(
    stats: &AcceptanceStats,
    lat: &LatencyModel,
    objective: crate::config::Objective,
    max_depth: usize,
    max_width: usize,
    w_verify_budget: usize,
) -> (usize, usize) {
    let mut best = (1usize, 1usize);
    let mut best_score = f64::MIN;
    for d in 1..=max_depth {
        for &w in crate::config::GRAPH_WIDTHS.iter().filter(|&&w| w <= max_width) {
            let aal = stats.expected_aal(d, w);
            let score = match objective {
                crate::config::Objective::Aal => aal,
                crate::config::Objective::Speedup => {
                    let w_v = (d * w + 1).min(w_verify_budget);
                    lat.speedup_tree(aal, &vec![w; d], w_v)
                }
            };
            if score > best_score {
                best_score = score;
                best = (d, w);
            }
        }
    }
    best
}

/// Selects the draft width maximising the configured objective given a
/// predicted depth — the greedy `W_draft` sub-decision of §4.2.
pub fn select_draft_width(
    stats: &AcceptanceStats,
    lat: &LatencyModel,
    objective: crate::config::Objective,
    depth: usize,
    max_width: usize,
    w_verify_budget: usize,
) -> usize {
    let mut best_w = 1;
    let mut best_score = f64::MIN;
    for &w in crate::config::GRAPH_WIDTHS.iter().filter(|&&w| w <= max_width) {
        let aal = stats.expected_aal(depth, w);
        let score = match objective {
            crate::config::Objective::Aal => aal,
            crate::config::Objective::Speedup => {
                // Verification scope grows with the tree size but is capped
                // by the budget; pruning refines it later.
                let w_v = (depth * w + 1).min(w_verify_budget);
                lat.speedup_tree(aal, &vec![w; depth], w_v)
            }
        };
        if score > best_score {
            best_score = score;
            best_w = w;
        }
    }
    best_w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Objective;

    fn toy_model() -> LatencyModel {
        // Flat-then-rising verifier curve (memory-bound then saturated),
        // like Fig. 5-(a).
        LatencyModel {
            drafter: LatencyCurve::new(&[(1, 1e-3), (8, 1.1e-3), (64, 2e-3)]),
            verifier: LatencyCurve::new(&[(1, 8e-3), (8, 8.2e-3), (16, 9e-3), (64, 20e-3)]),
            cpu_overhead: 5e-4,
        }
    }

    #[test]
    fn curve_interpolates_and_clamps() {
        let c = LatencyCurve::new(&[(1, 1.0), (3, 3.0)]);
        assert_eq!(c.at(0.5), 1.0);
        assert!((c.at(2.0) - 2.0).abs() < 1e-9);
        // extrapolates last slope
        assert!((c.at(5.0) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn curve_sorts_input_points() {
        let c = LatencyCurve::new(&[(8, 2.0), (1, 1.0)]);
        assert_eq!(c.widths, vec![1.0, 8.0]);
    }

    #[test]
    fn eq3_penalises_oversized_verification() {
        let m = toy_model();
        // Same AAL, bigger verification scope => lower speedup.
        let s_small = m.speedup_tree(3.0, &[4; 4], 16);
        let s_big = m.speedup_tree(3.0, &[4; 4], 64);
        assert!(s_small > s_big);
    }

    #[test]
    fn eq3_beats_eq1_approximation_awareness() {
        // AAL alone says deeper is always better; Eq. 3 must flag the
        // regime where extra drafting/verification stops paying.
        let m = toy_model();
        let shallow = m.speedup_tree(2.5, &[4; 2], 9);
        let deep = m.speedup_tree(2.8, &[4; 16], 64); // +0.3 AAL, 8× drafts
        assert!(shallow > deep);
    }

    #[test]
    fn acceptance_stats_converge_toward_signal() {
        let mut st = AcceptanceStats::default();
        for _ in 0..500 {
            st.record_step(4, true);
        }
        assert!(st.q(4) > 0.95);
        for _ in 0..500 {
            st.record_step(4, false);
        }
        assert!(st.q(4) < 0.05);
    }

    #[test]
    fn expected_aal_is_truncated_geometric() {
        let mut st = AcceptanceStats::default();
        st.q_by_width.iter_mut().for_each(|q| *q = 0.5);
        let aal = st.expected_aal(3, 4);
        assert!((aal - (1.0 + 0.5 + 0.25 + 0.125)).abs() < 1e-9);
    }

    #[test]
    fn rank_stats_track_hits() {
        let mut st = AcceptanceStats::default();
        for _ in 0..200 {
            st.record_rank(Some(0));
        }
        assert!(st.accept_by_rank[0] > 0.9);
        assert!(st.accept_by_rank[1] < 0.1);
        assert_eq!(st.rank_counts[0], 200);
    }

    #[test]
    fn width_selector_respects_objective() {
        let m = toy_model();
        let mut st = AcceptanceStats::default();
        // Make wider trees barely help acceptance...
        st.q_by_width = vec![0.70, 0.71, 0.72, 0.73, 0.74, 0.75, 0.76];
        let w_aal = select_draft_width(&st, &m, Objective::Aal, 6, 64, 64);
        let w_spd = select_draft_width(&st, &m, Objective::Speedup, 6, 64, 64);
        // ...then AAL maximisation picks the widest, the latency-aware
        // objective picks something narrower.
        assert_eq!(w_aal, 64);
        assert!(w_spd < 64, "speedup objective chose {w_spd}");
    }

    #[test]
    fn tpot_improves_with_aal_at_fixed_cost() {
        let m = toy_model();
        assert!(m.tpot(3.0, &[4; 4], 16) < m.tpot(2.0, &[4; 4], 16));
    }
}
