//! Tree explorer: visualises what the Equal-Growth Tree actually builds
//! for a context — the grown tree, the Eq. 3-pruned verification subtree,
//! and what the verifier accepted — using the real models.
//!
//! ```bash
//! cargo run --release --example tree_explorer [prompt_index]
//! ```

use yggdrasil::config::width_for;
use yggdrasil::engine::{profiling, Session};
use yggdrasil::objective::AcceptanceStats;
use yggdrasil::pruning::prune_for_objective;
use yggdrasil::runtime::Runtime;
use yggdrasil::sampling::{argmax, softmax_inplace, top_k};
use yggdrasil::tree::{grow_step, Frontier, TokenTree};

fn main() -> yggdrasil::Result<()> {
    let idx: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(0);
    let artifacts = std::path::Path::new("artifacts");
    let rt = Runtime::load(artifacts, &["dft-xs", "tgt-sm"])?;
    let lat = profiling::load_or_profile(
        &rt,
        "dft-xs",
        "tgt-sm",
        Some(&artifacts.join("profile.json")),
        3,
    )?;
    let prompts = yggdrasil::corpus::PromptSet::load(artifacts, "c4s")?;
    let prompt = &prompts.prompts[idx];

    let mut sess = Session::new(&rt, "dft-xs", "tgt-sm", 0, true)?;
    sess.prefill(prompt)?;
    let root = *sess.committed.last().unwrap();
    let root_pos = (sess.committed_len() - 1) as i32;
    println!("prompt: {prompt:?}\nroot token: {root} at position {root_pos}\n");

    // --- grow an EGT by hand (depth 4, width 4, top-8 candidates) -------
    let (depth, width, branch) = (4usize, 4usize, 8usize);
    let mut tree = TokenTree::new(root);
    let mut dslots = vec![None::<u32>];
    let mut frontier = Frontier::new(depth);
    let vocab = sess.drafter.spec.vocab;

    // head draft
    let slot = sess.drafter.slots.alloc(1).unwrap()[0];
    dslots[0] = Some(slot);
    let mask = sess
        .drafter
        .slots
        .mask_builder()
        .build(&tree, &[0], &dslots, 1)
        .to_vec();
    let req = sess
        .drafter
        .padded_request(1, &[root], &[root_pos], &[slot], &mask, sess.exec_mode());
    let reply = sess.rt.forward(req)?;
    let mut probs = reply.logits[..vocab].to_vec();
    softmax_inplace(&mut probs, 1.0);
    let cands: Vec<(u32, f32)> = top_k(&probs, branch).into_iter().map(|(i, p)| (i as u32, p)).collect();
    frontier.push_candidates(&tree, 0, cands);

    for step in 0..depth {
        let ids = grow_step(&mut tree, &mut frontier, width);
        if ids.is_empty() {
            break;
        }
        dslots.resize(tree.len(), None);
        let slots = sess.drafter.slots.alloc(ids.len()).unwrap();
        for (i, &id) in ids.iter().enumerate() {
            dslots[id] = Some(slots[i]);
        }
        let tokens: Vec<u32> = ids.iter().map(|&i| tree.token(i)).collect();
        let positions: Vec<i32> = ids.iter().map(|&i| root_pos + tree.depth(i) as i32).collect();
        let w = width_for(ids.len()).unwrap();
        let mask = sess.drafter.slots.mask_builder().build(&tree, &ids, &dslots, w).to_vec();
        let req = sess
            .drafter
            .padded_request(w, &tokens, &positions, &slots, &mask, sess.exec_mode());
        let reply = sess.rt.forward(req)?;
        for (i, &id) in ids.iter().enumerate() {
            let mut probs = reply.logits[i * vocab..(i + 1) * vocab].to_vec();
            softmax_inplace(&mut probs, 1.0);
            let cands: Vec<(u32, f32)> =
                top_k(&probs, branch).into_iter().map(|(j, p)| (j as u32, p)).collect();
            frontier.push_candidates(&tree, id, cands);
        }
        println!("growth step {step}: +{} nodes (equal growth)", ids.len());
    }

    println!("\ngrown tree ({} nodes, expected AAL {:.2}):", tree.len(), tree.expected_aal());
    println!("{}", tree.render(None));

    // --- prune to the Eq. 3-optimal verification subtree ----------------
    let (keep, w_verify) = prune_for_objective(&tree, &lat, &vec![width; depth], 32);
    println!(
        "pruned to {} nodes (graph width {w_verify}) by the latency-aware DP:",
        keep.len()
    );
    let (sub, _) = tree.induced_subtree(&keep);
    println!("{}", sub.render(None));

    // --- verify and walk --------------------------------------------------
    let vslots = sess.target.slots.alloc(keep.len()).unwrap();
    let mut vslot_of = vec![None::<u32>; tree.len()];
    for (i, &n) in keep.iter().enumerate() {
        vslot_of[n] = Some(vslots[i]);
    }
    let tokens: Vec<u32> = keep.iter().map(|&i| tree.token(i)).collect();
    let positions: Vec<i32> = keep.iter().map(|&i| root_pos + tree.depth(i) as i32).collect();
    let mask = sess
        .target
        .slots
        .mask_builder()
        .build(&tree, &keep, &vslot_of, w_verify)
        .to_vec();
    let req = sess
        .target
        .padded_request(w_verify, &tokens, &positions, &vslots, &mask, sess.exec_mode());
    let reply = sess.rt.forward(req)?;
    let tvocab = sess.target.spec.vocab;

    let mut cur = 0usize;
    let mut accepted = vec![0usize];
    loop {
        let row_i = keep.iter().position(|&k| k == cur).unwrap();
        let truth = argmax(&reply.logits[row_i * tvocab..(row_i + 1) * tvocab]) as u32;
        match tree
            .children(cur)
            .iter()
            .find(|&&c| keep.contains(&c) && tree.token(c) == truth)
        {
            Some(&c) => {
                accepted.push(c);
                cur = c;
            }
            None => {
                println!(
                    "accepted path: {:?} (+ bonus token {truth})",
                    accepted.iter().map(|&n| tree.token(n)).collect::<Vec<_>>()
                );
                break;
            }
        }
    }
    println!("accepted {} draft tokens + 1 bonus", accepted.len() - 1);

    // A taste of the width selector with live stats:
    let stats = AcceptanceStats::default();
    for w in [1usize, 2, 4, 8] {
        println!(
            "expected AAL at depth {depth} width {w}: {:.2} (prior q={:.2})",
            stats.expected_aal(depth, w),
            stats.q(w)
        );
    }
    Ok(())
}
