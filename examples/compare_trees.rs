//! Side-by-side engine comparison on one workload: runs the paper's
//! baseline matrix (vanilla, sequence spec, SpecInfer, Sequoia, vLLM-Spec,
//! Yggdrasil) over a handful of prompts and prints the Fig. 6-style
//! AAL / step-latency / TPOT table plus greedy-output equality checks.
//!
//! ```bash
//! cargo run --release --example compare_trees [dataset] [n_prompts]
//! ```

use yggdrasil::baselines::build_engine;
use yggdrasil::corpus::PromptSet;
use yggdrasil::engine::{profiling, Engine};
use yggdrasil::metrics::Table;
use yggdrasil::runtime::Runtime;

fn main() -> yggdrasil::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let dataset = args.first().map(|s| s.as_str()).unwrap_or("c4s").to_string();
    let n: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(3);
    let max_new = 48;

    let artifacts = std::path::Path::new("artifacts");
    let rt = Runtime::load(artifacts, &["dft-xs", "tgt-sm"])?;
    let lat = profiling::load_or_profile(
        &rt,
        "dft-xs",
        "tgt-sm",
        Some(&artifacts.join("profile.json")),
        5,
    )?;
    let prompts = PromptSet::load(artifacts, &dataset)?;

    let mut table = Table::new(&["engine", "AAL", "step_ms", "tpot_ms", "greedy_match"])
        .with_title(&format!("engine comparison on {dataset} ({n} prompts × {max_new} tokens)"));

    // Reference greedy outputs from the vanilla engine.
    let mut vanilla = build_engine(&rt, "vanilla", ("dft-xs", "tgt-sm"), &lat)?;
    let _ = vanilla.generate(&prompts.prompts[0], 4)?; // warm compiles
    let mut reference = Vec::new();
    let mut v_aal = 0.0;
    let mut v_step = 0.0;
    let mut v_tpot = 0.0;
    for p in prompts.prompts.iter().take(n) {
        let g = vanilla.generate(p, max_new)?;
        v_aal += g.aal();
        v_step += g.step_latency();
        v_tpot += g.tpot();
        reference.push(g.tokens);
    }
    table.row(&[
        "vanilla".into(),
        format!("{:.2}", v_aal / n as f64),
        format!("{:.2}", v_step * 1e3 / n as f64),
        format!("{:.2}", v_tpot * 1e3 / n as f64),
        "reference".into(),
    ]);

    for name in ["seqspec", "specinfer", "sequoia", "vllmspec", "yggdrasil"] {
        let mut e = build_engine(&rt, name, ("dft-xs", "tgt-sm"), &lat)?;
        let _ = e.generate(&prompts.prompts[0], 4)?; // warm compiles
        let mut aal = 0.0;
        let mut step = 0.0;
        let mut tpot = 0.0;
        let mut matches = 0usize;
        for (i, p) in prompts.prompts.iter().take(n).enumerate() {
            let g = e.generate(p, max_new)?;
            aal += g.aal();
            step += g.step_latency();
            tpot += g.tpot();
            matches += (g.tokens == reference[i]) as usize;
        }
        table.row(&[
            name.to_string(),
            format!("{:.2}", aal / n as f64),
            format!("{:.2}", step * 1e3 / n as f64),
            format!("{:.2}", tpot * 1e3 / n as f64),
            format!("{matches}/{n}"),
        ]);
    }
    println!("{}", table.to_markdown());
    Ok(())
}
