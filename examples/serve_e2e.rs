//! End-to-end serving driver (the repository's mandated E2E validation):
//! spins up the TCP server with the full Yggdrasil engine on the real
//! artifacts, fires a batch of concurrent client requests from the bundled
//! datasets, and reports per-request and aggregate latency/throughput —
//! the serving-paper analog of "load a small real model and serve batched
//! requests". The continuous-serving scheduler interleaves up to
//! `max_sessions` generations at verification-step granularity, so every
//! client streams tokens every scheduling round.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_e2e
//! ```

use std::time::Instant;

use yggdrasil::config::EngineConfig;
use yggdrasil::corpus::PromptSet;
use yggdrasil::engine::{profiling, SpecDecoder};
use yggdrasil::runtime::Runtime;
use yggdrasil::server::{Client, ServeOpts, Server};

fn main() -> yggdrasil::Result<()> {
    let artifacts = std::path::Path::new("artifacts");
    let quick = std::env::var("YGG_QUICK").is_ok();
    let n_requests: usize = if quick { 4 } else { 12 };
    let max_new = if quick { 24 } else { 48 };

    // Engine + server.
    let rt = Runtime::load(artifacts, &["dft-xs", "tgt-sm"])?;
    let lat = profiling::load_or_profile(
        &rt,
        "dft-xs",
        "tgt-sm",
        Some(&artifacts.join("profile.json")),
        5,
    )?;
    let engine = SpecDecoder::new(&rt, EngineConfig::default(), lat, None);
    let opts = ServeOpts { max_queue: 64, max_sessions: 4, ..ServeOpts::default() };
    let srv = Server::spawn("127.0.0.1:0", Box::new(engine), opts)?;
    println!("server listening on {}", srv.addr);

    // Workload: prompts from all three datasets, round-robin.
    let mut prompts = Vec::new();
    for ds in yggdrasil::corpus::DATASETS {
        let ps = PromptSet::load(artifacts, ds)?;
        prompts.extend(ps.prompts.into_iter().take(n_requests.div_ceil(3)));
    }
    prompts.truncate(n_requests);

    // Fire concurrent clients (interleaved on the single-tenant engine).
    let t0 = Instant::now();
    let addr = srv.addr;
    let handles: Vec<_> = prompts
        .into_iter()
        .enumerate()
        .map(|(i, prompt)| {
            std::thread::spawn(
                move || -> yggdrasil::Result<(usize, f64, usize, f64, f64, f64, f64)> {
                    let mut c = Client::connect(&addr)?;
                    let t = Instant::now();
                    let r = c.generate(i as u64, &prompt, max_new)?;
                    Ok((
                        i,
                        t.elapsed().as_secs_f64(),
                        r.tokens.len(),
                        r.aal,
                        r.tpot_ms,
                        r.ttft_ms,
                        r.queue_ms,
                    ))
                },
            )
        })
        .collect();

    let mut total_tokens = 0usize;
    let mut latencies = Vec::new();
    println!("\n  req   e2e_ms  tokens    AAL   engine_tpot_ms  ttft_ms  queue_ms");
    for h in handles {
        let (i, secs, tokens, aal, tpot_ms, ttft_ms, queue_ms) = h.join().unwrap()?;
        println!(
            "  {i:>3} {:>8.1} {tokens:>7} {aal:>6.2} {tpot_ms:>15.2} {ttft_ms:>8.1} {queue_ms:>9.1}",
            secs * 1e3
        );
        total_tokens += tokens;
        latencies.push(secs);
    }
    let wall = t0.elapsed().as_secs_f64();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p50 = latencies[latencies.len() / 2];
    let p99 = latencies[(latencies.len() * 99 / 100).min(latencies.len() - 1)];
    println!(
        "\n{} requests / {} tokens in {:.2}s — throughput {:.1} tok/s, e2e p50 {:.0}ms p99 {:.0}ms",
        n_requests,
        total_tokens,
        wall,
        total_tokens as f64 / wall,
        p50 * 1e3,
        p99 * 1e3
    );
    let snap = srv.stats.snapshot();
    println!(
        "server stats: {} requests, {} tokens, {} errors, {} cancelled — queue mean {:.1} ms, ttft p50 {:.1} ms",
        snap.requests, snap.tokens, snap.errors, snap.cancelled,
        snap.queue_delay_ms_mean, snap.ttft_ms_p50
    );
    Ok(())
}
