//! Quickstart: load the AOT artifacts, build the full Yggdrasil engine and
//! decode one prompt, printing tokens as they are accepted.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use yggdrasil::config::EngineConfig;
use yggdrasil::corpus::PromptSet;
use yggdrasil::engine::{profile_latency_model, Engine, SpecDecoder};
use yggdrasil::runtime::Runtime;

fn main() -> yggdrasil::Result<()> {
    let artifacts = std::path::Path::new("artifacts");

    // 1. Load the runtime: compiles the static-width HLO graphs lazily and
    //    uploads the weight blobs as resident device buffers.
    let rt = Runtime::load(artifacts, &["dft-xs", "tgt-sm"])?;

    // 2. Profile the hardware latency curves T_drafter(W) / T_verifier(W)
    //    that drive the Eq. 3 latency-aware objective.
    let lat = profile_latency_model(&rt, "dft-xs", "tgt-sm", 3)?;
    println!(
        "latency curves: T_d(1)={:.2}ms T_d(8)={:.2}ms | T_v(1)={:.2}ms T_v(64)={:.2}ms",
        lat.t_draft(1) * 1e3,
        lat.t_draft(8) * 1e3,
        lat.t_verify(1) * 1e3,
        lat.t_verify(64) * 1e3
    );

    // 3. Build the engine (EGT drafting + pruning + stage scheduling).
    let mut engine = SpecDecoder::new(&rt, EngineConfig::default(), lat, None);
    println!("engine: {}", engine.name());

    // 4. Decode one of the bundled dataset prompts, streaming tokens.
    let prompts = PromptSet::load(artifacts, "c4s")?;
    let prompt = &prompts.prompts[0];
    print!("tokens: ");
    let g = engine.generate_with(prompt, 48, &mut |toks| {
        for t in toks {
            print!("{t} ");
        }
        use std::io::Write as _;
        let _ = std::io::stdout().flush();
    })?;
    println!();
    println!(
        "\n{} tokens in {} verification steps — AAL {:.2}, {:.2} ms/token",
        g.tokens.len(),
        g.iterations,
        g.aal(),
        g.tpot() * 1e3
    );
    let r = &g.recorder;
    println!(
        "stage means (ms): head={:.2} tree={:.2} verify={:.2} accept={:.3} bookkeep={:.3}",
        r.mean("stage.head_draft") * 1e3,
        r.mean("stage.tree_draft") * 1e3,
        r.mean("stage.verify") * 1e3,
        r.mean("stage.accept") * 1e3,
        r.mean("stage.bookkeep") * 1e3,
    );
    Ok(())
}
