"""Layer-2: Llama-architecture decoder in JAX (build-time only).

Two forward paths share one parameter set:

  * ``forward_cached`` — the AOT graph. Operates on W tokens against a
    slot-indexed functional KV cache with an explicit attention-bias matrix,
    calling the Pallas tree-attention kernel (L1). This is the function
    lowered to HLO text per width W and executed from Rust; Python is never
    on the request path.
  * ``forward_train`` / ``sample_batch`` — dense batched paths used only at
    build time for corpus generation and drafter distillation.

Cache/slot model (DESIGN.md §7): the cache has a fixed capacity C of
"slots". Callers assign each incoming token an arbitrary slot; its K/V are
scattered there. Attention validity is *entirely* encoded in the bias
matrix, so committed tokens, tree tokens and garbage slots coexist without
compaction, and every operator shape is static — the property the paper's
Equal-Growth Tree needs for compile-time optimization.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .configs import ModelConfig
from .kernels.tree_attention import tree_attention
from .kernels.ref import tree_attention_ref

MASK_NEG = -1e9


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------

def param_spec(cfg: ModelConfig):
    """Ordered (name, shape) list — the canonical tensor order of the
    weights blob consumed by the Rust runtime (manifest order)."""
    d, f = cfg.d_model, cfg.ffn
    spec = [("embed", (cfg.vocab, d))]
    for i in range(cfg.layers):
        spec += [
            (f"l{i}.rms1", (d,)),
            (f"l{i}.wq", (d, d)),
            (f"l{i}.wk", (d, d)),
            (f"l{i}.wv", (d, d)),
            (f"l{i}.wo", (d, d)),
            (f"l{i}.rms2", (d,)),
            (f"l{i}.wgate", (d, f)),
            (f"l{i}.wup", (d, f)),
            (f"l{i}.wdown", (f, d)),
        ]
    spec.append(("final_norm", (d,)))
    return spec


def init_params(cfg: ModelConfig, key=None):
    """Deterministic seeded init. Norm gains start at 1, matmuls at
    scaled-normal — the usual pre-LN transformer init."""
    if key is None:
        key = jax.random.PRNGKey(cfg.seed)
    params = {}
    for name, shape in param_spec(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("rms1", "rms2")) or name == "final_norm":
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            fan_in = shape[0]
            params[name] = (
                jax.random.normal(sub, shape, jnp.float32) / np.sqrt(fan_in)
            )
    return params


def params_to_flat(params, cfg: ModelConfig):
    """Concatenate tensors in manifest order into one f32 vector."""
    return np.concatenate(
        [np.asarray(params[name], np.float32).reshape(-1) for name, _ in param_spec(cfg)]
    )


def flat_to_params(flat, cfg: ModelConfig):
    params, off = {}, 0
    for name, shape in param_spec(cfg):
        n = int(np.prod(shape))
        params[name] = jnp.asarray(flat[off : off + n], jnp.float32).reshape(shape)
        off += n
    assert off == len(flat)
    return params


# --------------------------------------------------------------------------
# Building blocks
# --------------------------------------------------------------------------

def rms_norm(x, gain, eps=1e-5):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * gain


def rope(x, positions, theta):
    """Rotary embedding with explicit integer positions.

    x: [..., H, Dh], positions: broadcastable integer array over the token
    axis (x.shape[:-2]). Explicit positions are what let tree tokens carry
    their *logical* depth while living at arbitrary cache slots.
    """
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions.astype(jnp.float32)[..., None, None] * freqs  # [..., 1, half]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _attn_proj(x, params, i, cfg):
    h, dh = cfg.heads, cfg.head_dim
    q = (x @ params[f"l{i}.wq"]).reshape(x.shape[:-1] + (h, dh))
    k = (x @ params[f"l{i}.wk"]).reshape(x.shape[:-1] + (h, dh))
    v = (x @ params[f"l{i}.wv"]).reshape(x.shape[:-1] + (h, dh))
    return q, k, v


def _mlp(x, params, i):
    gate = jax.nn.silu(x @ params[f"l{i}.wgate"])
    up = x @ params[f"l{i}.wup"]
    return (gate * up) @ params[f"l{i}.wdown"]


# --------------------------------------------------------------------------
# AOT path: slot-indexed cached forward (lowered per width W)
# --------------------------------------------------------------------------

def forward_cached(params, tokens, positions, slots, mask, cache, cfg: ModelConfig,
                   use_pallas=True):
    """The graph the Rust coordinator executes.

    Args:
      params:    dict of weight tensors (runtime: resident device buffers).
      tokens:    i32[W] token ids (draft-tree nodes, prefill chunk, …).
      positions: i32[W] logical sequence positions (RoPE), = node depth.
      slots:     i32[W] cache slots this call writes K/V into.
      mask:      f32[W, C] 1.0 where attention allowed (prefix ∪ ancestors
                 ∪ self), 0.0 otherwise. Padding rows may be all-zero.
      cache:     f32[L, 2, C, H, Dh] KV cache (functional: updated copy is
                 returned).
      cfg:       static model config.

    Returns: (logits f32[W, V], hidden f32[W, D], new_cache).
    """
    bias = (1.0 - mask) * MASK_NEG  # [W, C]
    x = params["embed"][tokens]  # [W, D]

    attn = tree_attention if use_pallas else tree_attention_ref
    new_layers = []
    for i in range(cfg.layers):
        hpre = rms_norm(x, params[f"l{i}.rms1"])
        q, k, v = _attn_proj(hpre, params, i, cfg)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        # Scatter this call's K/V into their slots *before* attention so
        # each token can see itself and its in-call ancestors.
        kc = cache[i, 0].at[slots].set(k)  # [C, H, Dh]
        vc = cache[i, 1].at[slots].set(v)
        o = attn(q, kc, vc, bias)  # [W, H, Dh] — L1 Pallas kernel
        x = x + o.reshape(x.shape[0], -1) @ params[f"l{i}.wo"]
        x = x + _mlp(rms_norm(x, params[f"l{i}.rms2"]), params, i)
        new_layers.append(jnp.stack([kc, vc]))

    hidden = rms_norm(x, params["final_norm"])  # [W, D]
    logits = (hidden @ params["embed"].T) * cfg.logit_scale
    return logits, hidden, jnp.stack(new_layers)


def make_cached_fn(cfg: ModelConfig, width: int, use_pallas=True):
    """Returns (fn, example_args) ready for jax.jit(...).lower().

    Argument order matches the Rust runtime's calling convention:
    tokens, positions, slots, mask, cache, then weight tensors in
    manifest order.
    """
    names = [n for n, _ in param_spec(cfg)]

    def fn(tokens, positions, slots, mask, cache, *weights):
        params = dict(zip(names, weights))
        return forward_cached(params, tokens, positions, slots, mask, cache,
                              cfg, use_pallas=use_pallas)

    c, h, dh, l = cfg.cache_capacity, cfg.heads, cfg.head_dim, cfg.layers
    example = [
        jax.ShapeDtypeStruct((width,), jnp.int32),
        jax.ShapeDtypeStruct((width,), jnp.int32),
        jax.ShapeDtypeStruct((width,), jnp.int32),
        jax.ShapeDtypeStruct((width, c), jnp.float32),
        jax.ShapeDtypeStruct((l, 2, c, h, dh), jnp.float32),
    ] + [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in param_spec(cfg)]
    return fn, example


# --------------------------------------------------------------------------
# Build-time dense paths (training / sampling; never AOT-exported)
# --------------------------------------------------------------------------

def forward_train(params, tokens, cfg: ModelConfig):
    """Dense causal forward over [B, T] — vectorised jnp attention."""
    b, t = tokens.shape
    h, dh = cfg.heads, cfg.head_dim
    positions = jnp.arange(t)
    causal = jnp.tril(jnp.ones((t, t), jnp.float32))
    bias = (1.0 - causal) * MASK_NEG

    x = params["embed"][tokens]  # [B, T, D]
    for i in range(cfg.layers):
        hpre = rms_norm(x, params[f"l{i}.rms1"])
        q, k, v = _attn_proj(hpre, params, i, cfg)
        q = rope(q, positions[None, :], cfg.rope_theta)
        k = rope(k, positions[None, :], cfg.rope_theta)
        scale = 1.0 / np.sqrt(dh)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale + bias
        p = jax.nn.softmax(scores, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", p, v).reshape(b, t, -1)
        x = x + o @ params[f"l{i}.wo"]
        x = x + _mlp(rms_norm(x, params[f"l{i}.rms2"]), params, i)

    hidden = rms_norm(x, params["final_norm"])
    return (hidden @ params["embed"].T) * cfg.logit_scale  # [B, T, V]


@functools.partial(jax.jit, static_argnames=("cfg", "steps", "temperature"))
def sample_batch(params, key, prompts, cfg: ModelConfig, steps: int,
                 temperature: float = 1.0):
    """Autoregressively extend [B, P] prompts by `steps` tokens.

    Uses a dense per-step KV cache under lax.scan; build-time only (corpus
    generation for distillation and dataset synthesis).
    Returns [B, P + steps] token ids.
    """
    b, p = prompts.shape
    total = p + steps
    h, dh, l = cfg.heads, cfg.head_dim, cfg.layers

    # Prefill via dense forward, rebuilding the cache tensors it implies.
    # (Cheaper and simpler than maintaining two cache codepaths.)
    def step_fn(carry, _):
        key, toks, pos = carry
        # Recompute over the visible prefix — O(T^2) total, fine at build
        # time for T<=96 and it keeps this function trivially correct.
        logits = forward_train(params, toks, cfg)  # [B, total, V]
        idx = pos - 1
        step_logits = logits[:, idx, :]
        key, sub = jax.random.split(key)
        if temperature == 0.0:
            nxt = jnp.argmax(step_logits, axis=-1)
        else:
            nxt = jax.random.categorical(sub, step_logits / temperature, axis=-1)
        toks = toks.at[:, pos].set(nxt)
        return (key, toks, pos + 1), None

    toks0 = jnp.zeros((b, total), jnp.int32).at[:, :p].set(prompts)
    (key, toks, _), _ = jax.lax.scan(step_fn, (key, toks0, p), None, length=steps)
    return toks
