"""Build-time language-model training on the synthetic chainlang corpus.

All four models (two targets, two drafters) are trained with a plain LM
cross-entropy objective on sequences sampled from the *same* ground-truth
language ([`compile.language.ChainLang`]) — the miniature analog of
Llama-68M and Llama-2-7B sharing a pre-training corpus. Capacity decides
how much of the second-order structure each model captures, which is what
produces realistic, context-dependent speculative acceptance (the earlier
distill-from-random-teacher approach only produced memorization; see
DESIGN.md §2).

Runs once inside ``make artifacts``; never on the request path.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from .model import forward_train, init_params


def adam_init(params):
    z = lambda: {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": z(), "v": z(), "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = {k: b1 * state["m"][k] + (1 - b1) * grads[k] for k in params}
    v = {k: b2 * state["v"][k] + (1 - b2) * jnp.square(grads[k]) for k in params}
    bc1 = 1 - b1 ** t.astype(jnp.float32)
    bc2 = 1 - b2 ** t.astype(jnp.float32)
    new = {
        k: params[k] - lr * (m[k] / bc1) / (jnp.sqrt(v[k] / bc2) + eps) for k in params
    }
    return new, {"m": m, "v": v, "t": t}


def lm_loss(params, tokens, cfg):
    logits = forward_train(params, tokens, cfg)
    logp = jax.nn.log_softmax(logits[:, :-1], -1)
    tgt = tokens[:, 1:]
    return -jnp.mean(jnp.take_along_axis(logp, tgt[..., None], -1))


def lm_train(cfg, corpus, steps, lr=3e-3, batch=16, log_every=80, held_out=None):
    """Trains a fresh `cfg` model on `corpus` [N, T]; returns
    (params, stats) with train/held-out loss trajectories."""
    params = init_params(cfg)
    opt = adam_init(params)
    key = jax.random.PRNGKey(cfg.seed)
    data = jnp.asarray(corpus, jnp.int32)
    vg = jax.jit(jax.value_and_grad(lambda p, toks: lm_loss(p, toks, cfg)))
    held = None if held_out is None else jnp.asarray(held_out, jnp.int32)
    eval_loss = jax.jit(lambda p, toks: lm_loss(p, toks, cfg))

    stats = {"loss": [], "held_loss": []}
    t0 = time.time()
    for step in range(steps):
        key, sub = jax.random.split(key)
        idx = jax.random.randint(sub, (batch,), 0, data.shape[0])
        loss, grads = vg(params, data[idx])
        params, opt = adam_update(params, grads, opt, lr)
        if step % log_every == 0 or step == steps - 1:
            stats["loss"].append(float(loss))
            msg = f"  [{cfg.name}] step {step:4d} loss {float(loss):.3f}"
            if held is not None:
                hl = float(eval_loss(params, held[:16]))
                stats["held_loss"].append(hl)
                msg += f" held {hl:.3f}"
            print(msg + f" ({time.time()-t0:.0f}s)", flush=True)
    return params, stats


def agreement_stats(tgt_params, tgt_cfg, dft_params, dft_cfg, held):
    """Held-out drafter/verifier agreement: top-1 match rate and top-8
    coverage of the verifier's greedy token — the quantities that become
    the speculative acceptance rates at decode time."""
    arr = jnp.asarray(held, jnp.int32)
    tl = forward_train(tgt_params, arr, tgt_cfg)
    dl = forward_train(dft_params, arr, dft_cfg)
    tn = jnp.argmax(tl, -1)
    order = jnp.argsort(dl, -1)[..., ::-1]
    top1 = float(jnp.mean((tn == order[..., 0]).astype(jnp.float32)))
    cov8 = float(jnp.mean(jnp.any(order[..., :8] == tn[..., None], -1).astype(jnp.float32)))
    peak = float(jnp.max(jax.nn.softmax(tl, -1), -1).mean())
    return {"top1_agreement": top1, "top8_coverage": cov8, "verifier_peak": peak}


def greedy_agreement(tgt_params, tgt_cfg, dft_params, dft_cfg, prompt, steps=24):
    """Agreement specifically on the verifier's greedy continuation — the
    decode-time failure mode the random-teacher approach exhibited."""
    toks = list(np.asarray(prompt))
    agree = 0
    for _ in range(steps):
        arr = jnp.asarray([toks], jnp.int32)
        vn = int(jnp.argmax(forward_train(tgt_params, arr, tgt_cfg)[0, -1]))
        dn = int(jnp.argmax(forward_train(dft_params, arr, dft_cfg)[0, -1]))
        agree += vn == dn
        toks.append(vn)
    return agree / steps
