"""The synthetic ground-truth language ("chainlang").

A random transformer's next-token function is incompressible — a small
drafter can only memorize it, which destroys the context-dependent
acceptance structure speculative decoding lives on. Instead we define a
*learnable* seeded stochastic language with graded difficulty and train
every model in the zoo on it (the Llama-68M / Llama-2-7B relationship in
miniature):

  * **first-order core** (easy): every token `t` has 4 successor
    candidates with Zipf-ish weights — pure bigram structure that even the
    2-layer drafter captures;
  * **second-order modulation** (hard): for tokens in the *ambiguous set*
    (25% of the vocabulary), the successor table instead depends on
    `(t_prev, t_prev2 mod CTX_CLASSES)` — the large verifier learns most
    of this, the small drafter much less, which is what makes acceptance
    genuinely context-dependent;
  * **noise floor**: with probability `NOISE` the next token is uniform —
    keeps the language aperiodic and acceptance < 1.

Everything is deterministic given SEED.
"""

import numpy as np

from .configs import VOCAB

SEED = 20250711
BRANCH = 4  # successor candidates per state
CTX_CLASSES = 16  # second-order context classes
AMBIG_FRAC = 0.25
NOISE = 0.08
WEIGHTS = np.array([0.55, 0.25, 0.12, 0.08])


class ChainLang:
    """Seeded sparse bigram/trigram language over the model vocabulary."""

    def __init__(self, vocab: int = VOCAB, seed: int = SEED):
        rng = np.random.default_rng(seed)
        self.vocab = vocab
        # First-order successor table [V, BRANCH].
        self.succ1 = rng.integers(0, vocab, size=(vocab, BRANCH))
        # Ambiguous tokens get second-order tables [V, CTX_CLASSES, BRANCH].
        self.ambiguous = rng.random(vocab) < AMBIG_FRAC
        self.succ2 = rng.integers(0, vocab, size=(vocab, CTX_CLASSES, BRANCH))

    def candidates(self, prev: int, prev2: int) -> np.ndarray:
        """Successor candidates for the context (prev2, prev)."""
        if self.ambiguous[prev]:
            return self.succ2[prev, prev2 % CTX_CLASSES]
        return self.succ1[prev]

    def next_dist(self, prev: int, prev2: int) -> np.ndarray:
        """True conditional distribution over the vocabulary."""
        p = np.full(self.vocab, NOISE / self.vocab)
        cands = self.candidates(prev, prev2)
        for c, w in zip(cands, WEIGHTS):
            p[c] += (1.0 - NOISE) * w
        return p

    def sample(self, rng: np.random.Generator, n_seqs: int, length: int) -> np.ndarray:
        """Samples [n_seqs, length] sequences from the chain."""
        out = np.zeros((n_seqs, length), dtype=np.int64)
        out[:, 0] = rng.integers(0, self.vocab, n_seqs)
        out[:, 1] = rng.integers(0, self.vocab, n_seqs)
        for t in range(2, length):
            for i in range(n_seqs):
                prev, prev2 = out[i, t - 1], out[i, t - 2]
                if rng.random() < NOISE:
                    out[i, t] = rng.integers(0, self.vocab)
                else:
                    cands = self.candidates(int(prev), int(prev2))
                    out[i, t] = cands[rng.choice(BRANCH, p=WEIGHTS / WEIGHTS.sum())]
        return out

    def sample_fast(self, rng: np.random.Generator, n_seqs: int, length: int) -> np.ndarray:
        """Vectorised sampler (same distribution as `sample`)."""
        out = np.zeros((n_seqs, length), dtype=np.int64)
        out[:, :2] = rng.integers(0, self.vocab, (n_seqs, 2))
        for t in range(2, length):
            prev = out[:, t - 1]
            prev2 = out[:, t - 2] % CTX_CLASSES
            amb = self.ambiguous[prev]
            cands = np.where(
                amb[:, None], self.succ2[prev, prev2], self.succ1[prev]
            )  # [n, BRANCH]
            pick = rng.choice(BRANCH, size=n_seqs, p=WEIGHTS / WEIGHTS.sum())
            nxt = cands[np.arange(n_seqs), pick]
            noise = rng.random(n_seqs) < NOISE
            nxt = np.where(noise, rng.integers(0, self.vocab, n_seqs), nxt)
            out[:, t] = nxt
        return out
