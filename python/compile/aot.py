"""AOT compiler driver: python runs ONCE here, never at serve time.

Produces under ``artifacts/``:

  * ``<model>_w<W>.hlo.txt``  — HLO *text* per (model, width): the static
    forward graphs the Rust runtime compiles with PJRT. Text, not
    ``.serialize()``: jax ≥ 0.5 emits HloModuleProto with 64-bit ids that
    xla_extension 0.5.1 rejects; the text parser reassigns ids.
  * ``<model>.weights.bin``   — f32 little-endian weight blob in manifest
    tensor order (all four models trained at build time on the chainlang
    corpus — see language.py / train.py).
  * ``manifest.json``         — model shapes, tensor offsets, graph files,
    calling convention, dataset prompt files, golden-vector index.
  * ``prompts_<dataset>.json``— synthetic prompt sets (paper-dataset analogs).
  * ``golden_<model>.bin``    — seeded input/output vectors for the Rust
    runtime integration test (exact-numerics cross-check).

Usage: ``python -m compile.aot --out-dir ../artifacts [--fast]``
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import configs
from .configs import GRAPH_WIDTHS, MODELS, DATASETS
from .language import ChainLang
from .train import agreement_stats, greedy_agreement, lm_train
from .model import (
    forward_cached,
    flat_to_params,
    make_cached_fn,
    param_spec,
    params_to_flat,
    sample_batch,
)


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build_weights(out_dir, fast, force):
    """Trains the model zoo on the chainlang corpus (see language.py /
    train.py); returns params per model plus held-out agreement stats.
    Weight blobs are cached on disk — re-running is a no-op."""
    # Per-model training budgets calibrated for the single-core CPU box:
    # the targets need the most steps to absorb the second-order structure.
    steps = {
        "tgt-sm": 150 if fast else 340,
        "tgt-lg": 100 if fast else 220,
        "dft-xs": 150 if fast else 320,
        "dft-sm": 150 if fast else 320,
    }
    lang = ChainLang()
    # Large corpus so models must generalise the transition structure
    # instead of memorising sequences (4096 seqs >> any model's capacity
    # to rote-learn at these sizes).
    corpus = lang.sample_fast(np.random.default_rng(7), 96 if fast else 4096, 64)
    held = lang.sample_fast(np.random.default_rng(999), 32, 64)

    all_params, stats = {}, {}

    def blob_path(name):
        return os.path.join(out_dir, f"{name}.weights.bin")

    for name in ["tgt-sm", "tgt-lg", "dft-xs", "dft-sm"]:
        cfg = MODELS[name]
        path = blob_path(name)
        if os.path.exists(path) and not force:
            flat = np.fromfile(path, dtype="<f4")
            print(f"[aot] {name}: reusing cached weights ({len(flat)} params)")
            all_params[name] = flat_to_params(flat, cfg)
            stats[name] = {"cached": True}
            continue
        t0 = time.time()
        params, st = lm_train(cfg, corpus, steps=steps[name], held_out=held)
        params_to_flat(params, cfg).astype("<f4").tofile(path)
        all_params[name] = params
        stats[name] = st
        print(f"[aot] {name}: trained in {time.time()-t0:.1f}s -> {path}")

    # Held-out acceptance structure: the numbers the decode-time AAL
    # ultimately comes from (recorded into the manifest for provenance).
    tgt_cfg = MODELS["tgt-sm"]
    for dft in ["dft-xs", "dft-sm"]:
        for tgt in ["tgt-sm", "tgt-lg"]:
            a = agreement_stats(
                all_params[tgt], MODELS[tgt], all_params[dft], MODELS[dft], held[:16]
            )
            a["greedy_agreement"] = greedy_agreement(
                all_params[tgt], MODELS[tgt], all_params[dft], MODELS[dft],
                held[0, :32],
            )
            stats[f"{dft}->{tgt}"] = a
            print(f"[aot] {dft}->{tgt}: {a}")
    _ = tgt_cfg
    return all_params, stats, corpus


def lower_graphs(out_dir, force):
    """Lower forward_cached for every (model, width) to HLO text."""
    graph_index = {}
    for name, cfg in MODELS.items():
        graph_index[name] = {}
        for w in GRAPH_WIDTHS:
            fname = f"{name}_w{w}.hlo.txt"
            path = os.path.join(out_dir, fname)
            graph_index[name][str(w)] = fname
            if os.path.exists(path) and not force:
                continue
            t0 = time.time()
            fn, example = make_cached_fn(cfg, w)
            lowered = jax.jit(fn).lower(*example)
            text = to_hlo_text(lowered)
            with open(path, "w") as f:
                f.write(text)
            print(f"[aot] lowered {fname}: {len(text)/1e6:.2f} MB "
                  f"({time.time()-t0:.1f}s)")
    return graph_index


def build_datasets(out_dir, params, corpus):
    """Synthetic prompt sets standing in for C4 / Wikipedia / CNN-Daily."""
    tcfg = MODELS["tgt-sm"]
    rng = np.random.default_rng(99)
    files = {}
    for ds, spec in DATASETS.items():
        key = jax.random.PRNGKey(abs(hash(ds)) % (2**31))
        n, plen = configs.PROMPTS_PER_DATASET, configs.PROMPT_LEN
        n_model = int(n * (1.0 - spec["random_frac"]))
        prompts = []
        if n_model:
            seeds = jax.random.randint(key, (n_model, 2), 0, tcfg.vocab)
            # sample from the world model at the dataset temperature
            toks = np.asarray(
                sample_batch(params["tgt-sm"], key, seeds, tcfg,
                             steps=plen - 2, temperature=spec["temperature"])
            )
            prompts.extend(toks[:, :plen].tolist())
        while len(prompts) < n:
            prompts.append(rng.integers(0, tcfg.vocab, plen).tolist())
        fname = f"prompts_{ds}.json"
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump({"dataset": ds, "spec": spec, "prompts": prompts}, f)
        files[ds] = fname
        print(f"[aot] dataset {ds}: {len(prompts)} prompts -> {fname}")
    return files


def build_golden(out_dir, all_params):
    """Seeded input/output vectors per model (width 4) for the Rust
    runtime's exact-numerics integration test.

    Layout (all f32 LE except noted): tokens i32[W], positions i32[W],
    slots i32[W], mask f32[W,C], cache f32[L,2,C,H,Dh] (zeros, not
    stored), then outputs logits f32[W,V], hidden f32[W,D],
    cache_checksum f32[1] (sum of returned cache).
    """
    index = {}
    w = 4
    for name, cfg in MODELS.items():
        rng = np.random.default_rng(cfg.seed + 5)
        c = cfg.cache_capacity
        tokens = rng.integers(0, cfg.vocab, w).astype("<i4")
        positions = np.arange(w).astype("<i4")
        slots = np.arange(w).astype("<i4")
        mask = np.tril(np.ones((w, w), np.float32))
        full_mask = np.zeros((w, c), "<f4")
        full_mask[:, :w] = mask
        cache = jnp.zeros((cfg.layers, 2, c, cfg.heads, cfg.head_dim), jnp.float32)
        logits, hidden, new_cache = forward_cached(
            all_params[name],
            jnp.asarray(tokens), jnp.asarray(positions), jnp.asarray(slots),
            jnp.asarray(full_mask), cache, cfg,
        )
        fname = f"golden_{name}.bin"
        with open(os.path.join(out_dir, fname), "wb") as f:
            tokens.tofile(f)
            positions.tofile(f)
            slots.tofile(f)
            full_mask.astype("<f4").tofile(f)
            np.asarray(logits, "<f4").tofile(f)
            np.asarray(hidden, "<f4").tofile(f)
            np.asarray([float(jnp.sum(new_cache))], "<f4").tofile(f)
        index[name] = {"file": fname, "width": w}
        print(f"[aot] golden {name} -> {fname}")
    return index


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--fast", action="store_true",
                    help="fewer training steps (CI mode)")
    ap.add_argument("--force", action="store_true",
                    help="rebuild even if outputs exist")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    t0 = time.time()
    all_params, stats, corpus = build_weights(args.out_dir, args.fast, args.force)
    graph_index = lower_graphs(args.out_dir, args.force)
    dataset_files = build_datasets(args.out_dir, all_params, corpus)
    golden_index = build_golden(args.out_dir, all_params)

    manifest = {
        "format_version": 1,
        "calling_convention": {
            "inputs": ["tokens i32[W]", "positions i32[W]", "slots i32[W]",
                        "mask f32[W,C]", "cache f32[L,2,C,H,Dh]",
                        "<weight tensors in manifest order>"],
            "outputs": ["logits f32[W,V]", "hidden f32[W,D]",
                         "cache f32[L,2,C,H,Dh]"],
            "note": "root tuple; runtime uses untupled buffer execution",
        },
        "models": {},
        "datasets": dataset_files,
        "golden": golden_index,
        "train_stats": {
            k: {kk: vv for kk, vv in v.items() if kk != "cached"} if isinstance(v, dict) else v
            for k, v in stats.items()
        },
    }
    for name, cfg in MODELS.items():
        tensors, off = [], 0
        for tname, shape in param_spec(cfg):
            n = int(np.prod(shape))
            tensors.append({"name": tname, "shape": list(shape), "offset": off})
            off += n
        manifest["models"][name] = {
            "layers": cfg.layers,
            "d_model": cfg.d_model,
            "heads": cfg.heads,
            "head_dim": cfg.head_dim,
            "ffn": cfg.ffn,
            "vocab": cfg.vocab,
            "cache_capacity": cfg.cache_capacity,
            "rope_theta": cfg.rope_theta,
            "logit_scale": cfg.logit_scale,
            "param_count": off,
            "tensors": tensors,
            "weights_file": f"{name}.weights.bin",
            "graphs": graph_index[name],
            "widths": list(GRAPH_WIDTHS),
            "role": "target" if name.startswith("tgt") else "drafter",
        }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] manifest written; total {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
