"""Pure-jnp correctness oracles for the Pallas kernels.

These are the reference semantics the kernels are tested against (pytest +
hypothesis sweeps in python/tests). They are also used directly by the
dense training-mode forward pass, where vectorised jnp attention is faster
than an interpreted Pallas kernel.
"""

import jax.numpy as jnp


def tree_attention_ref(q, k, v, bias):
    """Tree-masked multi-head attention over a slot-indexed KV cache.

    Args:
      q:    [W, H, Dh] query vectors for the W tree tokens in this call.
      k:    [C, H, Dh] key cache (all slots; invalid slots are masked out).
      v:    [C, H, Dh] value cache.
      bias: [W, C] additive attention bias. 0 where attention is allowed
            (causal prefix + tree ancestors + self), a large negative
            number where it is not.

    Returns:
      [W, H, Dh] attention outputs.
    """
    dh = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, dtype=q.dtype))
    # scores: [H, W, C]
    scores = jnp.einsum("whd,chd->hwc", q, k) * scale + bias[None, :, :]
    # Numerically-stable softmax. Fully-masked rows (padding) degrade to a
    # uniform distribution rather than NaN because the max is subtracted.
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("hwc,chd->whd", p, v)
    return out.astype(q.dtype)
