"""Pallas tree-attention kernel — the paper's verification hot-spot (L1).

Tree-based speculative decoding verifies W tree tokens in one forward pass;
each token attends to the committed causal prefix plus its own ancestors in
the draft tree. The tree topology is encoded in an additive bias matrix
(a runtime input with a *static shape*), which is precisely what makes the
Equal-Growth Tree compatible with AOT compilation: the kernel below is
lowered once per width W and never recompiled.

Hardware adaptation (paper targets CUDA, we target the TPU programming
model per DESIGN.md §3):

  * grid = (heads, W/BLOCK_W, C/BLOCK_C) — the threadblock analog is a
    (query-block × head) program instance.
  * BlockSpec streams K/V in BLOCK_C-sized key blocks HBM→VMEM, the
    shared-memory-tile analog; the bias tile rides the same index map.
  * Q·Kᵀ and P·V are jnp.dot over (BLOCK_W×Dh)·(Dh×BLOCK_C) tiles — MXU
    (systolic array) shaped work rather than WMMA fragments.
  * the running max / denominator / accumulator of the online softmax live
    in VMEM scratch across the key-block grid dimension.

Run with ``interpret=True`` on CPU: real TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute. Block sizes are parameters
so tests can exercise the multi-block accumulation path with small shapes.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default blocks for the CPU-interpret path: one query block spanning the
# whole width and one key block spanning the cache keeps the interpreted
# grid small (= heads) so build-time lowering stays fast. The TPU-targeted
# configuration analysed in DESIGN.md §Perf is BLOCK_W=8, BLOCK_C=128.
NEG_INF = -1e30


def _make_kernel(scale, kv_blocks):
    """Builds the kernel with VMEM scratch for the online-softmax carries."""

    def kernel(q_ref, k_ref, v_ref, bias_ref, o_ref, m_ref, l_ref):
        kb = pl.program_id(2)

        @pl.when(kb == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)
            m_ref[...] = jnp.full_like(m_ref, NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)

        q = q_ref[...].astype(jnp.float32)
        k = k_ref[...].astype(jnp.float32)
        v = v_ref[...].astype(jnp.float32)
        bias = bias_ref[...].astype(jnp.float32)

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale + bias

        m_cur = jnp.max(s, axis=-1)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, m_cur)
        m_ref[...] = m_new

        p = jnp.exp(s - m_new[:, None])
        l_cur = jnp.sum(p, axis=-1)
        alpha = jnp.exp(m_prev - m_new)

        l_ref[...] = l_ref[...] * alpha + l_cur
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
        o_ref[...] = o_ref[...] * alpha[:, None] + pv

        @pl.when(kb == kv_blocks - 1)
        def _finalize():
            o_ref[...] = o_ref[...] / l_ref[...][:, None]

    return kernel


@functools.partial(jax.jit, static_argnames=("block_w", "block_c"))
def tree_attention(q, k, v, bias, *, block_w=None, block_c=None):
    """Tree-masked attention. Same contract as kernels.ref.tree_attention_ref.

    Args:
      q:    [W, H, Dh] queries.
      k:    [C, H, Dh] key cache.
      v:    [C, H, Dh] value cache.
      bias: [W, C] additive bias (0 = allowed, very negative = masked).
      block_w / block_c: tile sizes; default to full extent (grid == heads),
        which keeps the interpreted grid minimal for AOT lowering. Tests
        pass smaller blocks to cover the multi-block streaming path.

    Returns: [W, H, Dh] attention output, dtype of q.
    """
    w, h, dh = q.shape
    c = k.shape[0]
    bw = block_w or w
    bc = block_c or c
    if w % bw != 0 or c % bc != 0:
        raise ValueError(f"block sizes must divide extents: W={w}%{bw}, C={c}%{bc}")
    kv_blocks = c // bc
    scale = 1.0 / float(dh) ** 0.5

    kernel = _make_kernel(scale, kv_blocks)

    # Layout note: heads are the leading grid axis so a program instance
    # sees contiguous [*, Dh] tiles; index maps pick (head, block) slices.
    grid = (h, w // bw, kv_blocks)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # q[W,H,Dh] -> tile [bw, Dh] at (head hi, q-block wi)
            pl.BlockSpec((bw, None, dh), lambda hi, wi, ki: (wi, hi, 0)),
            # k[C,H,Dh] -> tile [bc, Dh] at key block ki
            pl.BlockSpec((bc, None, dh), lambda hi, wi, ki: (ki, hi, 0)),
            pl.BlockSpec((bc, None, dh), lambda hi, wi, ki: (ki, hi, 0)),
            # bias[W,C] -> tile [bw, bc]
            pl.BlockSpec((bw, bc), lambda hi, wi, ki: (wi, ki)),
        ],
        out_specs=pl.BlockSpec((bw, None, dh), lambda hi, wi, ki: (wi, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((w, h, dh), q.dtype),
        scratch_shapes=[
            # Online-softmax carries, the VMEM-scratch analog of the CUDA
            # kernel's shared-memory running statistics.
            pl.MemoryRef(jax.core.ShapedArray((bw,), jnp.float32), pl.MemorySpace.ANY),
            pl.MemoryRef(jax.core.ShapedArray((bw,), jnp.float32), pl.MemorySpace.ANY),
        ],
        interpret=True,
    )(q, k, v, bias)
    return out


def vmem_bytes_estimate(block_w, block_c, dh):
    """Analytical VMEM footprint of one program instance (DESIGN.md §Perf).

    q + k + v + bias + out + softmax carries, fp32.
    """
    tiles = (
        block_w * dh  # q
        + 2 * block_c * dh  # k, v
        + block_w * block_c  # bias
        + block_w * dh  # out accumulator
        + 2 * block_w  # m, l carries
    )
    return tiles * 4


def mxu_utilization_estimate(block_w, block_c, dh, mxu=(128, 128)):
    """Fraction of MXU lanes busy for the two dots (DESIGN.md §Perf)."""
    def frac(m, n):
        return min(m, mxu[0]) * min(n, mxu[1]) / (mxu[0] * mxu[1])

    # QK^T: (bw x dh) @ (dh x bc); PV: (bw x bc) @ (bc x dh)
    return 0.5 * (frac(block_w, block_c) + frac(block_w, dh))
