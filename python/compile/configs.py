"""Model configurations for the Yggdrasil reproduction.

Four Llama-architecture models stand in for the paper's Llama-2-7B/13B
targets and Llama-68M/160M drafters (see DESIGN.md §2 for the substitution
rationale). All models share the vocabulary and head_dim so drafter and
verifier operate over the same token space.

``tgt-sm`` is the "world model": its random-but-peaked next-token
distribution *defines* the synthetic language. ``tgt-lg`` and both drafters
are distilled against it at build time so that acceptance rates are
genuinely context-dependent, which is the behaviour the paper's EGT and
depth predictor exploit.
"""

from dataclasses import dataclass, field


VOCAB = 1024
HEAD_DIM = 32
CACHE_CAPACITY = 320  # KV slots per model instance (prefix + tree + slack)
ROPE_THETA = 10000.0
# Widths for which a static forward graph is AOT-compiled. The Equal-Growth
# Tree only ever issues calls with one of these shapes.
GRAPH_WIDTHS = (1, 2, 4, 8, 16, 32, 64)
PROMPT_PAD = 64  # prefill bucket length (prompts are padded to this)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    layers: int
    d_model: int
    heads: int
    ffn: int
    vocab: int = VOCAB
    head_dim: int = HEAD_DIM
    cache_capacity: int = CACHE_CAPACITY
    rope_theta: float = ROPE_THETA
    # Multiplier on the output logits. With the trained chainlang zoo the
    # language's peakedness comes from the data (true top-1 ≈ 0.5), so the
    # scale stays neutral; it is kept as a config knob because it is baked
    # into the AOT graphs and the runtime manifest.
    logit_scale: float = 1.0
    seed: int = 0

    @property
    def param_count(self) -> int:
        d, f, l = self.d_model, self.ffn, self.layers
        per_layer = 4 * d * d + 3 * d * f + 2 * d
        return self.vocab * d + l * per_layer + d


# Paper analog: Llama-2-7B target.
TGT_SM = ModelConfig(name="tgt-sm", layers=6, d_model=256, heads=8, ffn=512, seed=1001)
# Paper analog: Llama-2-13B target (larger, distilled to agree with tgt-sm's
# language the way two sibling checkpoints agree on natural text).
TGT_LG = ModelConfig(name="tgt-lg", layers=8, d_model=320, heads=10, ffn=640, seed=1002)
# Paper analog: Llama-68M drafter.
DFT_XS = ModelConfig(name="dft-xs", layers=2, d_model=128, heads=4, ffn=256, seed=1003)
# Paper analog: Llama-160M drafter.
DFT_SM = ModelConfig(name="dft-sm", layers=3, d_model=160, heads=5, ffn=320, seed=1004)

MODELS = {m.name: m for m in (TGT_SM, TGT_LG, DFT_XS, DFT_SM)}
TARGETS = ("tgt-sm", "tgt-lg")
DRAFTERS = ("dft-xs", "dft-sm")

# Synthetic prompt distributions standing in for the paper's datasets.
# Each is characterised by how prompts are produced from the world model;
# the resulting acceptance-rate profiles differ the way C4 / Wikipedia /
# CNN-Daily differ in the paper (see DESIGN.md §2).
DATASETS = {
    "c4s": {"temperature": 0.8, "random_frac": 0.0},   # in-domain, easy
    "wiki": {"temperature": 1.2, "random_frac": 0.0},  # noisier
    "cnnd": {"temperature": 0.5, "random_frac": 0.5},  # mixed in/out-of-domain
}
PROMPTS_PER_DATASET = 64
PROMPT_LEN = 32
