"""L2 correctness: the cached slot-indexed forward vs the dense reference,
parameter blob round-trips, RoPE position handling, and tree semantics at
the model level.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import configs
from compile.model import (
    flat_to_params,
    forward_cached,
    forward_train,
    init_params,
    make_cached_fn,
    param_spec,
    params_to_flat,
    sample_batch,
)

CFG = configs.DFT_XS  # smallest model keeps the suite fast


@pytest.fixture(scope="module")
def params():
    return init_params(CFG)


def empty_cache(cfg=CFG):
    return jnp.zeros(
        (cfg.layers, 2, cfg.cache_capacity, cfg.heads, cfg.head_dim), jnp.float32
    )


def linear_mask(n, c):
    m = np.zeros((n, c), np.float32)
    m[:, :n] = np.tril(np.ones((n, n)))
    return jnp.asarray(m)


def test_cached_equals_dense_sequentially(params):
    rng = np.random.default_rng(0)
    toks = rng.integers(0, CFG.vocab, 10).astype(np.int32)
    dense = forward_train(params, jnp.asarray(toks[None]), CFG)[0]
    cache = empty_cache()
    c = CFG.cache_capacity
    for t in range(len(toks)):
        mask = jnp.zeros((1, c), jnp.float32).at[0, : t + 1].set(1.0)
        logits, hidden, cache = forward_cached(
            params,
            jnp.asarray([toks[t]]),
            jnp.asarray([t]),
            jnp.asarray([t]),
            mask,
            cache,
            CFG,
        )
        np.testing.assert_allclose(
            np.asarray(logits[0]), np.asarray(dense[t]), atol=5e-4, rtol=1e-4
        )


def test_cached_chunked_equals_dense(params):
    rng = np.random.default_rng(1)
    n = 8
    toks = rng.integers(0, CFG.vocab, n).astype(np.int32)
    dense = forward_train(params, jnp.asarray(toks[None]), CFG)[0]
    logits, _, _ = forward_cached(
        params,
        jnp.asarray(toks),
        jnp.arange(n, dtype=jnp.int32),
        jnp.arange(n, dtype=jnp.int32),
        linear_mask(n, CFG.cache_capacity),
        empty_cache(),
        CFG,
    )
    np.testing.assert_allclose(np.asarray(logits), np.asarray(dense), atol=5e-4, rtol=1e-4)


def test_slot_permutation_invariance(params):
    """Tokens may live at ANY cache slots — logits must not change."""
    rng = np.random.default_rng(2)
    n = 6
    toks = jnp.asarray(rng.integers(0, CFG.vocab, n), jnp.int32)
    pos = jnp.arange(n, dtype=jnp.int32)
    c = CFG.cache_capacity

    out_lin, _, _ = forward_cached(
        params, toks, pos, jnp.arange(n, dtype=jnp.int32),
        linear_mask(n, c), empty_cache(), CFG,
    )
    # Scatter the same tokens to arbitrary slots with an equivalent mask.
    slots = jnp.asarray([31, 7, 200, 99, 150, 3], jnp.int32)
    mask = np.zeros((n, c), np.float32)
    for i in range(n):
        for j in range(i + 1):
            mask[i, int(slots[j])] = 1.0
    out_scat, _, _ = forward_cached(
        params, toks, pos, slots, jnp.asarray(mask), empty_cache(), CFG
    )
    np.testing.assert_allclose(
        np.asarray(out_lin), np.asarray(out_scat), atol=5e-4, rtol=1e-4
    )


def test_tree_branch_equals_restart(params):
    """A tree branch must see exactly prefix+path: verifying tokens [a, b]
    as a tree branch under root r equals decoding them sequentially."""
    rng = np.random.default_rng(3)
    prefix = rng.integers(0, CFG.vocab, 4).astype(np.int32)
    r, a, b = 5, 17, 101
    c = CFG.cache_capacity

    # Sequential path.
    seq = np.concatenate([prefix, [r, a, b]]).astype(np.int32)
    dense = forward_train(params, jnp.asarray(seq[None]), CFG)[0]

    # Cached: prefill prefix+r linearly, then evaluate [a, b] as a chain at
    # scattered slots with a second sibling branch alongside.
    n0 = len(prefix) + 1
    _, _, cache = forward_cached(
        params,
        jnp.asarray(seq[:n0]),
        jnp.arange(n0, dtype=jnp.int32),
        jnp.arange(n0, dtype=jnp.int32),
        linear_mask(n0, c),
        empty_cache(),
        CFG,
    )
    # Tree: [a(5), b(6), sibling(5)] at slots [40, 41, 42].
    toks = jnp.asarray([a, b, 999], jnp.int32)
    pos = jnp.asarray([n0, n0 + 1, n0], jnp.int32)
    slots = jnp.asarray([40, 41, 42], jnp.int32)
    mask = np.zeros((3, c), np.float32)
    mask[:, :n0] = 1.0
    mask[0, 40] = 1.0
    mask[1, 40] = 1.0
    mask[1, 41] = 1.0
    mask[2, 42] = 1.0
    logits, _, _ = forward_cached(params, toks, pos, slots, jnp.asarray(mask), cache, CFG)
    np.testing.assert_allclose(np.asarray(logits[0]), np.asarray(dense[n0 - 1 + 1]), atol=5e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(logits[1]), np.asarray(dense[n0 - 1 + 2]), atol=5e-4, rtol=1e-4)


def test_padding_rows_do_not_perturb(params):
    """All-zero mask rows + trash slot writes must leave real rows intact."""
    rng = np.random.default_rng(4)
    toks = jnp.asarray(rng.integers(0, CFG.vocab, 2), jnp.int32)
    c = CFG.cache_capacity
    base_mask = linear_mask(2, c)
    out2, _, _ = forward_cached(
        params, toks, jnp.arange(2, dtype=jnp.int32), jnp.arange(2, dtype=jnp.int32),
        base_mask, empty_cache(), CFG,
    )
    # Same call padded to width 4.
    toks4 = jnp.concatenate([toks, jnp.zeros(2, jnp.int32)])
    pos4 = jnp.asarray([0, 1, 0, 0], jnp.int32)
    trash = c - 1
    slots4 = jnp.asarray([0, 1, trash, trash], jnp.int32)
    mask4 = jnp.zeros((4, c), jnp.float32).at[:2].set(base_mask)
    out4, _, _ = forward_cached(params, toks4, pos4, slots4, mask4, empty_cache(), CFG)
    np.testing.assert_allclose(np.asarray(out4[:2]), np.asarray(out2), atol=1e-4)
    assert np.all(np.isfinite(np.asarray(out4)))


def test_rope_relative_positions_matter(params):
    # RoPE encodes *relative* offsets: a lone self-attending token is
    # position-invariant, but the gap between a query and a cached key is
    # not — the same two tokens at distance 1 vs distance 9 must differ.
    c = CFG.cache_capacity
    toks = jnp.asarray([3, 5], jnp.int32)
    slots = jnp.asarray([0, 1], jnp.int32)
    mask = jnp.zeros((2, c), jnp.float32).at[0, 0].set(1.0).at[1, :2].set(1.0)
    near, _, _ = forward_cached(
        params, toks, jnp.asarray([0, 1], jnp.int32), slots, mask, empty_cache(), CFG,
    )
    far, _, _ = forward_cached(
        params, toks, jnp.asarray([0, 9], jnp.int32), slots, mask, empty_cache(), CFG,
    )
    # Row 0 (the key token, self-attending) is gap-independent…
    np.testing.assert_allclose(np.asarray(near[0]), np.asarray(far[0]), atol=1e-5)
    # …row 1 (query at distance 1 vs 9 from its key) is not.
    assert float(jnp.max(jnp.abs(near[1] - far[1]))) > 1e-4


def test_param_blob_roundtrip(params):
    flat = params_to_flat(params, CFG)
    assert flat.shape == (CFG.param_count,)
    back = flat_to_params(flat, CFG)
    for name, _ in param_spec(CFG):
        np.testing.assert_array_equal(np.asarray(params[name]), np.asarray(back[name]))


def test_param_spec_matches_count():
    for cfg in configs.MODELS.values():
        total = sum(int(np.prod(s)) for _, s in param_spec(cfg))
        assert total == cfg.param_count, cfg.name


def test_make_cached_fn_signature():
    fn, example = make_cached_fn(CFG, 4)
    assert len(example) == 5 + len(param_spec(CFG))
    assert example[0].shape == (4,)
    assert example[3].shape == (4, CFG.cache_capacity)
    lowered = jax.jit(fn).lower(*example)
    assert lowered is not None


def test_sample_batch_shapes_and_determinism(params):
    key = jax.random.PRNGKey(0)
    prompts = jnp.asarray([[1, 2], [3, 4]], jnp.int32)
    a = sample_batch(params, key, prompts, CFG, steps=6, temperature=1.0)
    b = sample_batch(params, key, prompts, CFG, steps=6, temperature=1.0)
    assert a.shape == (2, 8)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert np.all(np.asarray(a) < CFG.vocab)
    # Greedy sampling is temperature-0.
    g = sample_batch(params, key, prompts, CFG, steps=4, temperature=0.0)
    g2 = sample_batch(params, jax.random.PRNGKey(9), prompts, CFG, steps=4, temperature=0.0)
    np.testing.assert_array_equal(np.asarray(g), np.asarray(g2))


@settings(max_examples=8, deadline=None)
@given(n=st.integers(1, 8), seed=st.integers(0, 1000))
def test_hypothesis_cached_matches_dense(n, seed):
    params = init_params(CFG)
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, CFG.vocab, n).astype(np.int32)
    dense = forward_train(params, jnp.asarray(toks[None]), CFG)[0]
    logits, _, _ = forward_cached(
        params,
        jnp.asarray(toks),
        jnp.arange(n, dtype=jnp.int32),
        jnp.arange(n, dtype=jnp.int32),
        linear_mask(n, CFG.cache_capacity),
        empty_cache(),
        CFG,
    )
    np.testing.assert_allclose(np.asarray(logits), np.asarray(dense), atol=5e-4, rtol=1e-4)
