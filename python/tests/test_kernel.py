"""L1 correctness: the Pallas tree-attention kernel vs the pure-jnp oracle.

This is the core correctness signal for the compute hot-spot: hypothesis
sweeps shapes, block sizes and mask sparsity patterns; assert_allclose
against ref.py everywhere.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import tree_attention_ref
from compile.kernels.tree_attention import (
    mxu_utilization_estimate,
    tree_attention,
    vmem_bytes_estimate,
)


def rand_case(rng, w, c, h, dh, mask_density=0.5, pad_rows=0):
    q = jnp.asarray(rng.standard_normal((w, h, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((c, h, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((c, h, dh)), jnp.float32)
    allow = rng.random((w, c)) < mask_density
    allow[:, 0] = True  # at least one visible key per row
    for r in range(w - pad_rows, w):
        allow[r, :] = False  # fully-masked padding rows
    bias = jnp.where(jnp.asarray(allow), 0.0, -1e9).astype(jnp.float32)
    return q, k, v, bias


def assert_matches(q, k, v, bias, **kw):
    out = tree_attention(q, k, v, bias, **kw)
    ref = tree_attention_ref(q, k, v, bias)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)
    assert not np.any(np.isnan(np.asarray(out)))


def test_basic_single_block():
    rng = np.random.default_rng(0)
    assert_matches(*rand_case(rng, 4, 32, 2, 8))


def test_multi_key_block_streaming():
    rng = np.random.default_rng(1)
    assert_matches(*rand_case(rng, 8, 64, 4, 16), block_w=4, block_c=16)


def test_production_shape():
    # The exact shape the verifier graphs use: W=64, C=320, H=8, Dh=32.
    rng = np.random.default_rng(2)
    assert_matches(*rand_case(rng, 64, 320, 8, 32), block_c=64)


def test_width_one_decode_shape():
    rng = np.random.default_rng(3)
    assert_matches(*rand_case(rng, 1, 320, 8, 32))


def test_fully_masked_padding_rows_are_finite():
    rng = np.random.default_rng(4)
    q, k, v, bias = rand_case(rng, 8, 32, 2, 8, pad_rows=3)
    out = np.asarray(tree_attention(q, k, v, bias))
    assert np.all(np.isfinite(out))


def test_causal_mask_equals_dense_attention():
    # With a lower-triangular mask over slots 0..W the kernel must equal
    # ordinary causal attention.
    rng = np.random.default_rng(5)
    w, h, dh = 8, 2, 16
    q, k, v, _ = rand_case(rng, w, w, h, dh)
    causal = jnp.where(jnp.tril(jnp.ones((w, w))) > 0, 0.0, -1e9).astype(jnp.float32)
    assert_matches(q, k, v, causal)


def test_tree_sibling_isolation():
    # Two sibling branches must not attend to each other: the output for a
    # row depends only on its visible keys.
    rng = np.random.default_rng(6)
    w, c, h, dh = 2, 8, 2, 8
    q = jnp.asarray(rng.standard_normal((w, h, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((c, h, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((c, h, dh)), jnp.float32)
    mask = np.full((w, c), -1e9, np.float32)
    mask[0, 0] = 0.0  # row 0 sees slot 0 only
    mask[1, 1] = 0.0  # row 1 sees slot 1 only
    out = tree_attention(q, k, v, jnp.asarray(mask))
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(v[0]), atol=1e-5)
    np.testing.assert_allclose(np.asarray(out[1]), np.asarray(v[1]), atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    w_pow=st.integers(0, 4),
    c_mult=st.integers(1, 5),
    h=st.sampled_from([1, 2, 4]),
    dh=st.sampled_from([8, 16, 32]),
    density=st.floats(0.05, 1.0),
    seed=st.integers(0, 2**16),
)
def test_hypothesis_shape_sweep(w_pow, c_mult, h, dh, density, seed):
    rng = np.random.default_rng(seed)
    w = 2**w_pow
    c = 16 * c_mult
    assert_matches(*rand_case(rng, w, c, h, dh, mask_density=density))


@settings(max_examples=15, deadline=None)
@given(
    bw_pow=st.integers(0, 3),
    bc_idx=st.integers(0, 2),
    seed=st.integers(0, 2**16),
)
def test_hypothesis_block_sweep(bw_pow, bc_idx, seed):
    # Block sizes must not change the numerics.
    rng = np.random.default_rng(seed)
    w, c, h, dh = 8, 48, 2, 16
    bw = 2**bw_pow
    bc = [16, 24, 48][bc_idx]
    assert_matches(*rand_case(rng, w, c, h, dh), block_w=bw, block_c=bc)


def test_rejects_non_dividing_blocks():
    rng = np.random.default_rng(7)
    q, k, v, bias = rand_case(rng, 8, 32, 2, 8)
    with pytest.raises(ValueError):
        tree_attention(q, k, v, bias, block_w=3)


def test_dtype_bfloat16_inputs_accumulate_in_f32():
    # TPU-style mixed precision: bf16 q/k/v with an f32 bias and f32
    # accumulation inside the kernel (the kernel upcasts tiles on load).
    rng = np.random.default_rng(8)
    q, k, v, bias = rand_case(rng, 4, 32, 2, 8)
    out = tree_attention(
        q.astype(jnp.bfloat16).astype(jnp.float32),
        k.astype(jnp.bfloat16).astype(jnp.float32),
        v.astype(jnp.bfloat16).astype(jnp.float32),
        bias,
    )
    ref = tree_attention_ref(q, k, v, bias)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=0.05, rtol=0.05)


def test_perf_estimators_are_sane():
    # DESIGN.md §Perf: VMEM footprint of the TPU-targeted tile must fit the
    # ~16 MiB VMEM budget with double buffering.
    bytes_tile = vmem_bytes_estimate(block_w=8, block_c=128, dh=32)
    assert bytes_tile * 2 < 16 * 2**20
    util = mxu_utilization_estimate(8, 128, 32)
    assert 0.0 < util <= 1.0
    # Bigger tiles use the MXU better.
    assert mxu_utilization_estimate(64, 128, 32) > mxu_utilization_estimate(1, 128, 32)
