"""Artifact-bundle consistency: the manifest, weight blobs, HLO graphs,
prompt sets and golden vectors must agree with each other and with the
model configs. Skipped cleanly when `make artifacts` has not run.
"""

import json
import os

import numpy as np
import pytest

from compile import configs
from compile.configs import GRAPH_WIDTHS, MODELS
from compile.model import param_spec

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ART, "manifest.json")

pytestmark = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="artifacts not built"
)


@pytest.fixture(scope="module")
def manifest():
    with open(MANIFEST) as f:
        return json.load(f)


def test_manifest_lists_all_models(manifest):
    assert set(manifest["models"]) == set(MODELS)
    for name, spec in manifest["models"].items():
        cfg = MODELS[name]
        assert spec["layers"] == cfg.layers
        assert spec["d_model"] == cfg.d_model
        assert spec["vocab"] == cfg.vocab
        assert spec["cache_capacity"] == cfg.cache_capacity
        assert spec["widths"] == list(GRAPH_WIDTHS)
        assert spec["param_count"] == cfg.param_count


def test_weight_blobs_match_manifest(manifest):
    for name, spec in manifest["models"].items():
        path = os.path.join(ART, spec["weights_file"])
        blob = np.fromfile(path, dtype="<f4")
        assert blob.shape == (spec["param_count"],), name
        assert np.all(np.isfinite(blob)), name
        # A trained model is not at init: norm gains must have moved.
        assert blob.std() > 1e-3


def test_tensor_layout_tiles_blob(manifest):
    for name, spec in manifest["models"].items():
        cfg = MODELS[name]
        expect = [(n, list(s)) for n, s in param_spec(cfg)]
        got = [(t["name"], t["shape"]) for t in spec["tensors"]]
        assert got == expect, name
        off = 0
        for t in spec["tensors"]:
            assert t["offset"] == off
            off += int(np.prod(t["shape"]))
        assert off == spec["param_count"]


def test_hlo_graphs_exist_and_mention_shapes(manifest):
    for name, spec in manifest["models"].items():
        for w, fname in spec["graphs"].items():
            path = os.path.join(ART, fname)
            assert os.path.exists(path), fname
            head = open(path).read(4000)
            assert "HloModule" in head
            # Graph signature includes the width-shaped token input.
            assert f"s32[{w}]" in head, (name, w)


def test_prompt_sets_are_valid(manifest):
    for ds, fname in manifest["datasets"].items():
        with open(os.path.join(ART, fname)) as f:
            data = json.load(f)
        assert data["dataset"] == ds
        prompts = data["prompts"]
        assert len(prompts) == configs.PROMPTS_PER_DATASET
        arr = np.asarray(prompts)
        assert arr.shape[1] == configs.PROMPT_LEN
        assert arr.min() >= 0 and arr.max() < configs.VOCAB


def test_golden_vectors_sized_exactly(manifest):
    for name, g in manifest["golden"].items():
        spec = manifest["models"][name]
        w = g["width"]
        c = spec["cache_capacity"]
        expect = 4 * (3 * w + w * c + w * spec["vocab"] + w * spec["d_model"] + 1)
        size = os.path.getsize(os.path.join(ART, g["file"]))
        assert size == expect, name


def test_train_stats_show_generalizing_zoo(manifest):
    stats = manifest.get("train_stats", {})
    pair = stats.get("dft-xs->tgt-sm")
    if not pair:
        pytest.skip("stats not recorded in this bundle")
    # The acceptance regime the experiments rely on: meaningful top-1
    # agreement, strong top-8 coverage, working greedy continuation.
    assert pair["top1_agreement"] > 0.3
    assert pair["top8_coverage"] > 0.6
    assert pair["greedy_agreement"] > 0.3
