"""Properties of the chainlang synthetic language (compile/language.py):
determinism, distribution correctness, and the graded-difficulty structure
the speculative-acceptance experiments rely on.
"""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.language import AMBIG_FRAC, BRANCH, ChainLang, CTX_CLASSES, NOISE, WEIGHTS


def test_deterministic_given_seed():
    a, b = ChainLang(seed=1), ChainLang(seed=1)
    np.testing.assert_array_equal(a.succ1, b.succ1)
    np.testing.assert_array_equal(a.succ2, b.succ2)
    c = ChainLang(seed=2)
    assert not np.array_equal(a.succ1, c.succ1)


def test_next_dist_is_normalized_and_spiked():
    lang = ChainLang()
    p = lang.next_dist(5, 17)
    assert abs(p.sum() - 1.0) < 1e-9
    # The top candidate carries most of the non-noise mass.
    top = np.sort(p)[::-1]
    assert top[0] >= (1.0 - NOISE) * WEIGHTS[0] - 1e-9
    # Noise floor everywhere.
    assert p.min() >= NOISE / lang.vocab - 1e-12


def test_ambiguous_fraction_is_plausible():
    lang = ChainLang()
    frac = lang.ambiguous.mean()
    assert abs(frac - AMBIG_FRAC) < 0.05


def test_second_order_modulation_only_on_ambiguous_tokens():
    lang = ChainLang()
    amb = int(np.argmax(lang.ambiguous))
    plain = int(np.argmin(lang.ambiguous))
    # Plain tokens: successors independent of prev2.
    np.testing.assert_array_equal(lang.candidates(plain, 0), lang.candidates(plain, 7))
    # Ambiguous tokens: at least one context class differs.
    diffs = [
        not np.array_equal(lang.candidates(amb, a), lang.candidates(amb, b))
        for a in range(CTX_CLASSES)
        for b in range(a + 1, CTX_CLASSES)
    ]
    assert any(diffs)


def test_samplers_agree_in_distribution():
    lang = ChainLang()
    rng = np.random.default_rng(0)
    seqs = lang.sample_fast(rng, 64, 32)
    assert seqs.shape == (64, 32)
    assert seqs.min() >= 0 and seqs.max() < lang.vocab
    # Empirical next-token hit rate vs the analytic top-BRANCH coverage:
    # 1 - NOISE of transitions should land in the candidate set.
    hits = 0
    total = 0
    for row in seqs:
        for t in range(2, len(row)):
            cands = lang.candidates(int(row[t - 1]), int(row[t - 2]))
            hits += int(row[t]) in cands.tolist()
            total += 1
    rate = hits / total
    assert abs(rate - (1.0 - NOISE)) < 0.05, rate


@settings(max_examples=20, deadline=None)
@given(prev=st.integers(0, 1023), prev2=st.integers(0, 10_000))
def test_candidates_shape_and_range(prev, prev2):
    lang = ChainLang()
    c = lang.candidates(prev, prev2)
    assert c.shape == (BRANCH,)
    assert c.min() >= 0 and c.max() < lang.vocab


def test_sample_fast_deterministic_per_rng_seed():
    lang = ChainLang()
    a = lang.sample_fast(np.random.default_rng(3), 8, 16)
    b = lang.sample_fast(np.random.default_rng(3), 8, 16)
    np.testing.assert_array_equal(a, b)
