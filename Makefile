# Convenience entry points; see README.md for the full tour.

.PHONY: artifacts test figures fmt doc

# AOT-compile the L2 model graphs + weights into rust/artifacts/ (one-off;
# needs the Python toolchain with JAX). The root symlink keeps the Python
# parity tests — which look for ./artifacts — working too.
artifacts:
	cd python && python3 -m compile.aot --out-dir ../rust/artifacts
	ln -sfn rust/artifacts artifacts

# Tier-1 verification: build + the artifact-free unit/property/server tests
# (artifact-gated tests skip cleanly when `make artifacts` has not run).
test:
	cd rust && cargo build --release && cargo test -q

# Regenerate every paper table/figure (requires artifacts).
figures:
	cd rust && cargo run --release -- figures --exp all

fmt:
	cd rust && cargo fmt

# The documented-surface gate CI enforces.
doc:
	cd rust && RUSTDOCFLAGS="-D warnings" cargo doc --no-deps
