# Convenience entry points; see README.md for the full tour.

.PHONY: artifacts test figures fmt doc serve serve-equal serve-nodraft serve-noprefix smoke smoke-prefix smoke-hol smoke-alloc smoke-shard smoke-trace bench-maskpath

# AOT-compile the L2 model graphs + weights into rust/artifacts/ (one-off;
# needs the Python toolchain with JAX). The root symlink keeps the Python
# parity tests — which look for ./artifacts — working too.
artifacts:
	cd python && python3 -m compile.aot --out-dir ../rust/artifacts
	ln -sfn rust/artifacts artifacts

# Tier-1 verification: build + the artifact-free unit/property/server tests
# (artifact-gated tests skip cleanly when `make artifacts` has not run).
test:
	cd rust && cargo build --release && cargo test -q

# Regenerate every paper table/figure (requires artifacts).
figures:
	cd rust && cargo run --release -- figures --exp all

fmt:
	cd rust && cargo fmt

# The documented-surface gate CI enforces.
doc:
	cd rust && RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

# Serve with the paged shared KV cache (DESIGN.md §10; the default —
# tune with --block-size / --cache-blocks).
serve:
	cd rust && cargo run --release -- serve --addr 127.0.0.1:7777 --max-sessions 4

# Equal-partition fallback layout (DESIGN.md §9).
serve-equal:
	cd rust && cargo run --release -- serve --addr 127.0.0.1:7777 --max-sessions 4 --equal-partition

# Verify-only batching (DESIGN.md §9): drafts issue serially per session
# — the --no-batch-draft escape hatch for debugging the §11 draft packer.
serve-nodraft:
	cd rust && cargo run --release -- serve --addr 127.0.0.1:7777 --max-sessions 4 --no-batch-draft

# Paged serving without the cross-request prefix cache (DESIGN.md §12
# off): every request prefills its whole prompt.
serve-noprefix:
	cd rust && cargo run --release -- serve --addr 127.0.0.1:7777 --max-sessions 4 --no-prefix-cache

# Headless mock-engine serving smoke (no artifacts needed; CI runs this).
smoke:
	cd rust && cargo run --release -- figures --exp serving_mock

# Headless shared-system-prompt prefix-cache smoke (DESIGN.md §12; CI
# runs this too — enforces the ≥2× prefill-reduction bar).
smoke-prefix:
	cd rust && cargo run --release -- figures --exp serving_prefix_mock

# Headless head-of-line-blocking smoke (DESIGN.md §14; CI runs this
# too — a mid-wave long prompt must leave warm p95 ITL ≤ 1.5× baseline).
smoke-hol:
	cd rust && cargo run --release -- figures --exp serving_hol_mock

# Headless round-allocator smoke (DESIGN.md §15; CI runs this too —
# adaptive budgets must beat uniform on tok/s at no worse p95 ITL, and
# identical acceptance profiles must stay bit-exact with uniform).
smoke-alloc:
	cd rust && cargo run --release -- figures --exp serving_alloc_mock

# Headless multi-worker sharding smoke (DESIGN.md §16; CI runs this
# too — 4 mock workers must reach ≥3.5× one worker's tok/s, and
# affinity routing ≥1.5× round-robin's prefix hit rate).
smoke-shard:
	cd rust && cargo run --release -- figures --exp serving_shard_mock

# Headless observability smoke (DESIGN.md §17; CI runs this too —
# valid Prometheus exposition, balanced lifecycle/round/stage spans,
# a round-tripping Chrome export, and recorder overhead < 5% wall).
smoke-trace:
	cd rust && cargo run --release -- figures --exp serving_trace_mock

# Boolean-vs-bit-packed mask/walk microbench sweep (DESIGN.md §13):
# asserts bit-exact parity, then writes results/BENCH_maskpath.json.
# CI runs this in smoke mode (YGG_BENCH_QUICK=1).
bench-maskpath:
	cd rust && cargo bench --bench tree_ops
